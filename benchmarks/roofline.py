"""Kernel roofline micro-benchmark: pipeline depth and fusion effects.

Three measurements, each reported with arithmetic intensity (flop/byte
over the HBM traffic the kernel *must* move) so the numbers sit on a
roofline rather than floating as bare microseconds:

  * ``log_matmul`` at pipeline depth 1 (grid formulation) vs the
    depth>=2 manual async-copy pipeline — same numerics (bit-exact),
    different schedule; on TPU the depth-2 row shows whether the
    next-tile fetch actually hides behind the current tile's compute;
  * ``fused_softmax_div`` depth 1 vs depth >= 2 — the row-slab pipeline
    with in-flight output write-back;
  * decode attention before/after the flash fusion: the registry's
    separate-passes jnp path (score matmul + mask + stats + value
    matmul + combine divide, each materialised) vs the fused
    flash-decode kernel whose intermediates never visit HBM.

Off-TPU the Pallas rows run under the interpreter, where wall time
measures python dispatch, not memory systems — the module is then a
bit-rot gate (``--smoke``) proving every schedule still executes, and
the printed arithmetic intensities are the shape-derived constants a
TPU run would place on its roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as be
from repro.kernels.spec import KernelSpec, PipelineSpec

DEPTHS = (1, 2)


def _bench(fn, *args, iters: int = 10) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _row(label, us, flops, bytes_moved):
    return (label, us, flops / max(us, 1e-9) / 1e3,  # GFLOP/s
            flops / bytes_moved)                     # flop/byte


def run(seed: int = 0, shrink: int = 1, iters: int = 10):
    from repro.kernels.flash_attn.ops import flash_decode_attn
    from repro.kernels.fused_div.ops import fused_softmax_div
    from repro.kernels.log_matmul.ops import log_matmul

    rng = np.random.default_rng(seed)
    bk = be.resolve_backend_name(None)
    interpret = bk != "pallas"
    # the interpreter is a correctness path: per-op python dispatch
    # makes real shapes take minutes — shrink aggressively
    shrink = max(shrink, 16 if interpret else 1)
    rows = []

    # -- matmul depth sweep ------------------------------------------------
    m, n, k = max(8, 512 // shrink), max(128, 2048 // shrink), 512
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    mm_flops = 2.0 * m * n * k
    mm_bytes = 4.0 * (m * k + k * n + m * n)
    for depth in DEPTHS:
        spec = KernelSpec(pipeline=PipelineSpec(depth=depth))
        us = _bench(lambda a, b: log_matmul(
            a, b, "rapid10", spec=spec, interpret=interpret), x, w,
            iters=iters)
        rows.append(_row(f"log_matmul_{m}x{n}x{k}/depth{depth}[{bk}]",
                         us, mm_flops, mm_bytes))

    # -- fused softmax depth sweep ----------------------------------------
    sm, sn = max(8, 4096 // shrink), max(128, 4096 // shrink)
    e = jnp.asarray(np.abs(rng.normal(size=(sm, sn))) + 1e-3, jnp.float32)
    sm_flops = 4.0 * sm * sn          # exp-weights + sum + divide order
    sm_bytes = 4.0 * 2 * sm * sn      # one read + one write per element
    for depth in DEPTHS:
        spec = KernelSpec(pipeline=PipelineSpec(depth=depth))
        us = _bench(lambda a: fused_softmax_div(
            a, "rapid9", spec=spec, interpret=interpret), e, iters=iters)
        rows.append(_row(f"fused_softmax_{sm}x{sn}/depth{depth}[{bk}]",
                         us, sm_flops, sm_bytes))

    # -- decode attention: separate passes vs fused flash kernel ----------
    b, c, kv, g, hd = 4, max(128, 4096 // shrink), 2, 4, 64
    qf = jnp.asarray(rng.normal(size=(b, kv, g, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, c, kv, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, c, kv, hd)), jnp.float32)
    sp = jnp.asarray(rng.integers(0, 10 * c, size=(b, c)), jnp.int32)
    at_flops = 2.0 * 2 * b * kv * g * c * hd      # scores + values
    # fused traffic: q + caches + positions in, output out (stats never
    # leave VMEM); the separate-passes path additionally round-trips the
    # [B, KV, G, C] score/weight tensors
    at_bytes = 4.0 * (b * kv * g * hd * 2 + 2 * b * c * kv * hd + b * c)
    from repro.kernels.flash_attn.ref import decode_attn_ref
    separate = jax.jit(lambda q_, k_, v_, s_: decode_attn_ref(
        q_, k_, v_, s_, 8 * c, 0, "rapid9"))
    us = _bench(separate, qf, kc, vc, sp, iters=iters)
    rows.append(_row(f"decode_attn_c{c}/separate[jnp]", us, at_flops,
                     at_bytes + 4.0 * 2 * b * kv * g * c))
    us = _bench(lambda q_, k_, v_, s_: flash_decode_attn(
        q_, k_, v_, s_, 8 * c, 0, "rapid9", interpret=interpret),
        qf, kc, vc, sp, iters=iters)
    rows.append(_row(f"decode_attn_c{c}/flash[{bk}]", us, at_flops,
                     at_bytes))
    return rows


def main(smoke: bool = False):
    print("name,us,gflops,flop_per_byte")
    # smoke: 32x-shrunk shapes, one rep — proves every schedule (both
    # pipeline depths and the fused flash path) still executes
    rows = run(shrink=32, iters=1) if smoke else run()
    for name, us, gf, ai in rows:
        print(f"{name},{us:.1f},{gf:.3f},{ai:.2f}")


if __name__ == "__main__":
    main()
