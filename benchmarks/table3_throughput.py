"""Paper Table III — throughput columns, TPU-adapted.

The FPGA metric (cycles @ f_max per LUT) has no direct CPU analogue; what
transfers is the *relative op cost*: RAPID replaces an exact multiply
(divide) with int add + 256-LUT gather.  We measure wall time of the jnp
formulations under jit on this host (proxy) and report the structural op
counts per element (the TPU-relevant number — VPU ops replace MXU/divide
ops).  The real target-hardware numbers are the roofline terms from the
dry-run (benchmarks/roofline_report.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import float_approx as fa


def _bench(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(n: int = 1 << 20, seed: int = 0, mm_shape=(256, 512, 256)):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.5, 100, n), jnp.float32)
    b = jnp.asarray(rng.uniform(0.5, 100, n), jnp.float32)
    lut_m = jnp.asarray(fa.mul_lut("rapid10"))
    lut_d = jnp.asarray(fa.div_lut("rapid9"))

    exact_mul = jax.jit(lambda x, y: x * y)
    exact_div = jax.jit(lambda x, y: x / y)
    rapid_mul = jax.jit(lambda x, y: fa.log_mul_f32(x, y, lut_m))
    rapid_div = jax.jit(lambda x, y: fa.log_div_f32(x, y, lut_d))

    rows = [
        ("mul_exact", _bench(exact_mul, a, b)),
        ("mul_rapid10", _bench(rapid_mul, a, b)),
        ("div_exact", _bench(exact_div, a, b)),
        ("div_rapid9", _bench(rapid_div, a, b)),
    ]
    # matmul: exact dot vs logarithmic, routed through the backend
    # registry (the resolved name is reported so CI logs show which
    # execution path RAPID_BACKEND / autodetect actually picked)
    from repro.core import backend as be
    from repro.core.ops import qmatmul
    bk = be.resolve_backend_name(None)
    M, K, N = mm_shape
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    mm_exact = jax.jit(lambda x, w: qmatmul(x, w, None))
    mm_rapid = jax.jit(lambda x, w: qmatmul(x, w, "rapid10", backend=bk))
    mm_fused = jax.jit(lambda x, w: qmatmul(x, w, "rapid10", backend=bk,
                                            bias=bias, activation="silu"))
    tag = f"{M}x{K}x{N}"
    rows.append((f"matmul_exact_{tag}", _bench(mm_exact, x, w)))
    rows.append((f"matmul_rapid_{tag}[{bk}]", _bench(mm_rapid, x, w)))
    rows.append((f"matmul_rapid_fused_bias_silu[{bk}]", _bench(mm_fused, x, w)))
    return rows


def main(smoke: bool = False):
    print("name,us_per_call,derived")
    # smoke: tiny elementwise arrays + a deliberately degenerate matmul
    # (K=130 is the shape class the block heuristics used to mis-tile)
    rows = run(n=1 << 12, mm_shape=(24, 130, 12)) if smoke else run()
    for name, us in rows:
        print(f"{name},{us:.1f},cpu-proxy")
    print("# structural per-element cost (TPU target): exact f32 mul = 1 MXU"
          " mul-add lane; RAPID mul = 1 int32 add + 1 x 256-entry VMEM gather"
          " + 3 select  (divider identical with subtract) — see roofline")


if __name__ == "__main__":
    main()
