"""Paper Table III — accuracy columns (ARE / PRE / error bias) for every
multiplier and divider scheme at 8/16/32-bit (mul) and 8/4, 16/8, 32/16
(div).  8-bit is exhaustive; wider widths are Monte-Carlo (uniform over
the whole interval, like the paper's methodology)."""
from __future__ import annotations

import numpy as np

from repro.core import schemes as S
from repro.core.mitchell import mitchell_div_np, mitchell_mul_np

# paper Table III reference values: (ARE%, PRE%) per (scheme, width)
PAPER_MUL = {
    ("mitchell", 8): (3.77, 11.11), ("mitchell", 16): (3.85, 11.11),
    ("mitchell", 32): (3.91, 11.11),
    ("rapid3", 8): (1.02, 6.1), ("rapid3", 16): (1.03, 6.1),
    ("rapid3", 32): (1.05, 6.1),
    ("rapid5", 8): (0.91, 4.45), ("rapid5", 16): (0.93, 4.45),
    ("rapid5", 32): (0.95, 4.45),
    ("rapid10", 8): (0.64, 3.69), ("rapid10", 16): (0.56, 3.69),
    ("rapid10", 32): (0.58, 3.64),
}
PAPER_DIV = {
    ("mitchell", 4): (3.90, 13.0), ("mitchell", 8): (4.11, 13.0),
    ("mitchell", 16): (4.19, 13.0),
    ("rapid3", 4): (0.99, 5.74), ("rapid3", 8): (1.02, 5.74),
    ("rapid3", 16): (1.04, 5.74),
    ("rapid5", 4): (0.79, 4.34), ("rapid5", 8): (0.79, 4.34),
    ("rapid5", 16): (0.79, 4.34),
    ("rapid9", 4): (0.58, 3.48), ("rapid9", 8): (0.58, 3.48),
    ("rapid9", 16): (0.61, 3.48),
}


def _stats(approx, exact):
    re = approx / exact - 1.0
    return (100 * np.abs(re).mean(), 100 * np.abs(re).max(),
            100 * re.mean())


def run(samples: int = 1_000_000, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    for nb in (8, 16, 32):
        if nb == 8:
            a = np.repeat(np.arange(1, 256), 255)
            b = np.tile(np.arange(1, 256), 255)
        else:
            a = rng.integers(1, 1 << nb, samples)
            b = rng.integers(1, 1 << nb, samples)
        exact = a.astype(np.float64) * b
        for name, sch in S.MUL_SCHEMES.items():
            are, pre, bias = _stats(
                mitchell_mul_np(a, b, sch, nb, quantize=False), exact)
            p = PAPER_MUL.get((name, nb), (None, None))
            rows.append(("mul", nb, name, are, pre, bias, p[0], p[1]))
    for nb in (4, 8, 16):
        a = rng.integers(1, 1 << (2 * nb), samples)
        b = rng.integers(1, 1 << nb, samples)
        m = a < (b.astype(np.object_) << nb if nb >= 32 else b.astype(np.int64) << nb)
        a, b = a[m], b[m]
        exact = a.astype(np.float64) / b
        for name, sch in S.DIV_SCHEMES.items():
            are, pre, bias = _stats(
                mitchell_div_np(a, b, sch, nb, quantize=False), exact)
            p = PAPER_DIV.get((name, nb), (None, None))
            rows.append((f"div", 2 * nb, name, are, pre, bias, p[0], p[1]))
    return rows


def main(csv: bool = True, smoke: bool = False):
    # smoke: enough samples for the stats to be finite, not meaningful
    rows = run(samples=20_000) if smoke else run()
    print("op,bits,scheme,ARE%,PRE%,bias%,paper_ARE%,paper_PRE%")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]},{r[3]:.3f},{r[4]:.2f},{r[5]:+.3f},"
              f"{r[6] if r[6] is not None else ''},"
              f"{r[7] if r[7] is not None else ''}")
    return rows


if __name__ == "__main__":
    main()
