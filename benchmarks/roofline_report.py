"""SSRoofline — aggregate the dry-run JSONs into the per-(arch x shape)
roofline table: three terms, dominant bottleneck, MODEL_FLOPS ratio."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh: str = "pod1", approx: bool = False):
    rows = []
    for f in sorted(DRYRUN_DIR.glob(f"*__{mesh}{'__rapid' if approx else ''}.json")):
        if approx != f.stem.endswith("__rapid"):
            continue
        rec = json.loads(f.read_text())
        rows.append(rec)
    return rows


def main(mesh: str = "pod1", smoke: bool = False):
    # smoke is a no-op here: the report only aggregates whatever dry-run
    # JSONs exist (none in CI -> header-only output, still exercised)
    del smoke
    rows = load(mesh)
    print("arch,shape,dominant,compute_s,memory_s,collective_s,"
          "mem_GiB,useful_flops_ratio,coll_GB,status")
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']},{r['shape']},-,-,-,-,-,-,-,SKIP")
            continue
        if "error" in r:
            print(f"{r['arch']},{r['shape']},-,-,-,-,-,-,-,FAIL")
            continue
        t = r["roofline"]
        print(f"{r['arch']},{r['shape']},{t['dominant']},"
              f"{t['compute_s']:.3e},{t['memory_s']:.3e},"
              f"{t['collective_s']:.3e},"
              f"{r['memory']['per_device_total']/2**30:.2f},"
              f"{(r.get('useful_flops_ratio') or 0):.3f},"
              f"{r['hlo_analysis']['collectives_per_dev']['total']/1e9:.2f},OK")
    return rows


if __name__ == "__main__":
    import sys

    main(sys.argv[1] if len(sys.argv) > 1 else "pod1")
