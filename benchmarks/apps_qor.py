"""Paper Figs. 8-10 — end-to-end QoR of the three applications under
accurate / RAPID / Mitchell / truncated arithmetic."""
from __future__ import annotations

from repro.apps import harris, jpeg, pan_tompkins

PAPER = {
    "jpeg_psnr": {"accurate": 30.9, "rapid": 28.7, "truncated": 24.4},
    "harris_vectors": {"accurate": 100.0, "rapid": 94.0, "truncated": 83.0},
}


def main():
    print("app,variant,metric,value,paper_value")
    jr = jpeg.run(n_images=2, size=192)
    for k, v in jr.items():
        print(f"jpeg,{k},psnr_db,{v:.2f},{PAPER['jpeg_psnr'].get(k, '')}")
    pr = pan_tompkins.run(n_beats=30)
    for k, v in pr.items():
        print(f"pan_tompkins,{k},sensitivity,{v['sensitivity']:.3f},~1.0")
        print(f"pan_tompkins,{k},psnr_db,{v['psnr_vs_accurate_db']},>=28")
    hr = harris.run(n_images=2, size=160)
    for k, v in hr.items():
        print(f"harris,{k},correct_vectors_pct,{v},"
              f"{PAPER['harris_vectors'].get(k, '')}")
    return {"jpeg": jr, "pan_tompkins": pr, "harris": hr}


if __name__ == "__main__":
    main()
