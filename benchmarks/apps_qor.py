"""Paper Figs. 8-10 — end-to-end QoR of the three applications under
accurate / RAPID / Mitchell / truncated arithmetic."""
from __future__ import annotations

from repro.apps import harris, jpeg, pan_tompkins

PAPER = {
    "jpeg_psnr": {"accurate": 30.9, "rapid": 28.7, "truncated": 24.4},
    "harris_vectors": {"accurate": 100.0, "rapid": 94.0, "truncated": 83.0},
}


def main(smoke: bool = False):
    print("app,variant,metric,value,paper_value")
    # smoke: one tiny image / a few beats per app — executes every
    # variant's pipeline, makes no QoR claim
    variants = ("accurate", "rapid") if smoke else (
        "accurate", "rapid", "rapid5", "mitchell", "truncated")
    jr = jpeg.run(variants, n_images=1 if smoke else 2,
                  size=64 if smoke else 192)
    for k, v in jr.items():
        print(f"jpeg,{k},psnr_db,{v:.2f},{PAPER['jpeg_psnr'].get(k, '')}")
    pr = pan_tompkins.run(variants, n_beats=8 if smoke else 30)
    for k, v in pr.items():
        print(f"pan_tompkins,{k},sensitivity,{v['sensitivity']:.3f},~1.0")
        print(f"pan_tompkins,{k},psnr_db,{v['psnr_vs_accurate_db']},>=28")
    hr = harris.run(variants, n_images=1 if smoke else 2,
                    size=96 if smoke else 160)
    for k, v in hr.items():
        print(f"harris,{k},correct_vectors_pct,{v},"
              f"{PAPER['harris_vectors'].get(k, '')}")
    return {"jpeg": jr, "pan_tompkins": pr, "harris": hr}


if __name__ == "__main__":
    main()
