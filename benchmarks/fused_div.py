"""Fused-divider micro-benchmark: one pass vs reduce+divide round-trips.

Measures the registry's divider family on the shapes that dominate the
serving path — the decode-softmax combine (exp-weights / row-sum over
the KV length) and the model-zoo norms (d_model rows) — comparing

  * ``unfused``  — the pre-fusion composition: a separate row reduction
    (sum / mean+sqrt) materialised between two elementwise launches,
    with the RAPID divide bolted on (``qdiv``);
  * ``fused``    — the registry op (``qsoftmax_div`` / ``qrms_div``):
    denominator reduction and divide in one pass (one Pallas kernel
    launch on TPU; on this host the jnp formulation, so the wall-time
    delta is a lower bound — the HBM round-trip it removes only exists
    on the real accelerator).

The resolved backend name is reported so CI logs show which execution
path RAPID_BACKEND / autodetect picked.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as be
from repro.core.ops import qdiv, qrms_div, qsoftmax_div

# (label, rows, width): decode softmax at 4k/32k KV, norm at 4k d_model
SHAPES = [
    ("softmax_decode_4k", 128 * 32, 4096),
    ("softmax_decode_32k", 128, 32768),
    ("rms_norm_4k_dmodel", 4096, 4096),
]


def _bench(fn, *args, iters: int = 10) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(seed: int = 0, shrink: int = 1, iters: int = 10):
    rng = np.random.default_rng(seed)
    bk = be.resolve_backend_name(None)
    # the interpreter is a correctness path, not a speed path: per-op
    # python dispatch makes full-size rows take minutes — shrink 16x
    shrink = max(shrink, 16 if bk == "pallas-interpret" else 1)
    rows = []
    for label, m, n in SHAPES:
        m = max(8, m // shrink)
        n = max(128, n // shrink)
        x = jnp.asarray(np.abs(rng.normal(size=(m, n))) + 1e-3, jnp.float32)
        if label.startswith("softmax"):
            unfused = jax.jit(lambda e: qdiv(
                e, jnp.maximum(e.sum(-1, keepdims=True), 1e-20), "rapid9",
                backend=bk))
            fused = jax.jit(lambda e: qsoftmax_div(e, "rapid9", bk))
        else:
            unfused = jax.jit(lambda x: qdiv(
                x, jnp.sqrt(jnp.mean(jnp.square(x), -1, keepdims=True)
                            + 1e-6), "rapid9", backend=bk))
            fused = jax.jit(lambda x: qrms_div(x, 1e-6, "rapid9", bk))
        t_un = _bench(unfused, x, iters=iters)
        t_fu = _bench(fused, x, iters=iters)
        rows.append((f"{label}[{bk}]", t_un, t_fu))
    return rows


def main(smoke: bool = False):
    print("name,unfused_us,fused_us,speedup")
    # smoke: 32x-shrunk rows, one rep — executes the whole fused-divider
    # path (wrapper padding included) without measuring anything
    rows = run(shrink=32, iters=1) if smoke else run()
    for name, t_un, t_fu in rows:
        print(f"{name},{t_un:.1f},{t_fu:.1f},{t_un / t_fu:.2f}x")


if __name__ == "__main__":
    main()
