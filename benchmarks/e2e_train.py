"""End-to-end trainability: loss curves exact vs RAPID arithmetic on a
reduced model (the framework-level claim that near-unbiased approximate
arithmetic trains — paper SSV-B error-bias discussion + SSVI outlook)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RAPID, get_config
from repro.data.pipeline import SyntheticLM
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.train.optimizer import OptConfig
from repro.train.trainstep import make_train_step


def run(steps: int = 40, seed: int = 0):
    ctx = ParallelCtx()
    out = {}
    for mode in ("exact", "rapid"):
        cfg = get_config("yi_6b").reduced().with_(
            n_layers=2, d_model=64, d_ff=128, head_dim=16)
        if mode == "rapid":
            cfg = cfg.with_(approx=RAPID)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(seed))
        init_opt, step = make_train_step(
            m, OptConfig(lr=3e-3, warmup_steps=5, total_steps=steps), ctx)
        opt = init_opt(params)
        src = SyntheticLM(cfg.vocab_size, 32, 8, seed)
        sfun = jax.jit(step, donate_argnums=(0, 1))
        losses = []
        for i in range(steps):
            params, opt, mt = sfun(params, opt, src.batch_at(i), jnp.int32(i))
            losses.append(float(mt["loss"]))
        out[mode] = losses
    return out


def main(smoke: bool = False):
    # smoke: a handful of steps — proves the exact AND rapid train
    # steps still build and run, not that they converge
    res = run(steps=4) if smoke else run()
    print("step,loss_exact,loss_rapid")
    for i, (a, b) in enumerate(zip(res["exact"], res["rapid"])):
        if i % 5 == 0 or i == len(res["exact"]) - 1:
            print(f"{i},{a:.4f},{b:.4f}")
    gap = abs(res["exact"][-1] - res["rapid"][-1])
    print(f"# final-loss gap: {gap:.4f} (near-unbiased arithmetic trains)")
    return res


if __name__ == "__main__":
    main()
