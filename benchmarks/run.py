"""Benchmark harness: one module per paper table/figure.

  table3_accuracy    Table III accuracy columns (ARE/PRE/bias, all widths)
  table3_throughput  Table III throughput columns (CPU proxy + op costs)
  fused_div          fused divider family vs reduce+divide round-trips
  apps_qor           Figs. 8-10 end-to-end application QoR
  e2e_train          trainability of RAPID arithmetic (loss curves)
  roofline_report    SSRoofline table from the dry-run artifacts

``python -m benchmarks.run [name ...] [--smoke]`` — no names runs
everything.  ``--smoke`` runs every module at tiny shapes / one rep so
CI can prove the whole harness still executes (a bit-rot gate, not a
measurement); any sub-benchmark that raises is reported with its
traceback and the process exits non-zero.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

ALL = ["table3_accuracy", "table3_throughput", "fused_div", "apps_qor",
       "e2e_train", "roofline_report"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*", default=[],
                    help=f"benchmarks to run (default: all of {ALL})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, one rep: CI bit-rot gate")
    args = ap.parse_args(argv)
    unknown = [n for n in args.names if n not in ALL]
    if unknown:
        ap.error(f"unknown benchmarks {unknown}; have {ALL}")
    names = args.names or ALL
    failures = []
    for name in names:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(smoke=args.smoke)
            print(f"===== {name} done in {time.time()-t0:.1f}s =====")
        except Exception as e:  # keep the harness going, fail at exit
            failures.append(name)
            traceback.print_exc()
            print(f"===== {name} FAILED: {type(e).__name__}: {e} =====")
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
