"""Benchmark harness: one module per paper table/figure.

  table3_accuracy    Table III accuracy columns (ARE/PRE/bias, all widths)
  table3_throughput  Table III throughput columns (CPU proxy + op costs)
  fused_div          fused divider family vs reduce+divide round-trips
  apps_qor           Figs. 8-10 end-to-end application QoR
  e2e_train          trainability of RAPID arithmetic (loss curves)
  roofline_report    SSRoofline table from the dry-run artifacts

``python -m benchmarks.run [name ...]`` — no args runs everything.
"""
from __future__ import annotations

import sys
import time

ALL = ["table3_accuracy", "table3_throughput", "fused_div", "apps_qor",
       "e2e_train", "roofline_report"]


def main(names=None) -> int:
    names = names or ALL
    failures = []
    for name in names:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"===== {name} done in {time.time()-t0:.1f}s =====")
        except Exception as e:  # keep the harness going
            failures.append(name)
            print(f"===== {name} FAILED: {type(e).__name__}: {e} =====")
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:] or None))
