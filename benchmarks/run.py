"""Benchmark harness: one module per paper table/figure.

  table3_accuracy    Table III accuracy columns (ARE/PRE/bias, all widths)
  table3_throughput  Table III throughput columns (CPU proxy + op costs)
  fused_div          fused divider family vs reduce+divide round-trips
  apps_qor           Figs. 8-10 end-to-end application QoR
  e2e_train          trainability of RAPID arithmetic (loss curves)
  roofline           kernel roofline: pipeline depth 1 vs 2 and the
                     fused flash-attention kernel vs separate passes
  roofline_report    SSRoofline table from the dry-run artifacts
  serve_load         continuous batching vs fixed-slot under a Poisson
                     arrival trace (tokens/s + p50/p99 latency)

``python -m benchmarks.run [name ...] [--smoke]`` — no names runs
everything.  ``--smoke`` runs every module at tiny shapes / one rep so
CI can prove the whole harness still executes (a bit-rot gate, not a
measurement); any sub-benchmark that raises is reported with its
traceback and the process exits non-zero.

Regression gate: ``--json PATH`` writes a machine-readable result file
(per-benchmark status + wall seconds); ``--baseline PATH`` diffs the
run against a committed reference (``BENCH_baseline.json`` at the repo
root) and fails when a benchmark present in the baseline is missing,
failed, or slower than ``--tolerance`` x its baseline wall time.  The
tolerance is deliberately generous — CI runners are noisy; the gate is
for order-of-magnitude rot (an accidentally-quadratic path, an
interpreter fallback), not microbenchmarking.  Sub-second baselines are
compared against ``tolerance * max(wall, MIN_GATED_WALL_S)`` so timer
jitter on trivial modules cannot fail a PR.

Benchmarks new in this run (no baseline row) are not gated, but a
gated run *auto-records* the ones that passed into the baseline
artifact — same mode only (smoke vs full) — so the module that skipped
the gate once is gated from its second run onward instead of silently
forever.

Tuning: ``--retune`` re-runs the KernelSpec autotuner
(``repro.kernels.autotune.retune``) for the host platform *before* the
benchmarks, rewriting ``--tune-baseline`` (default
``TUNE_baseline.json``); the benchmarks then run against the fresh
winners.  Off-TPU the tuner's objective is a deterministic static cost
model, so a CI ``--retune`` reproduces the committed file byte-for-byte
— the bench-gate job diff-checks it for uncommitted drift.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

ALL = ["table3_accuracy", "table3_throughput", "fused_div", "apps_qor",
       "e2e_train", "roofline", "roofline_report", "serve_load"]

#: Below this baseline wall time, the time gate compares against
#: tolerance * MIN_GATED_WALL_S instead (pure-jitter regime).
MIN_GATED_WALL_S = 2.0


def compare_to_baseline(results: dict, baseline: dict,
                        tolerance: float) -> list:
    """Diff a run's results against a baseline; return regression strings.

    ``results`` / ``baseline`` are ``{name: {"status", "wall_s"}}``.
    Regressions: a baseline benchmark that is missing or failed in this
    run, or whose wall time exceeds
    ``tolerance * max(baseline_wall, MIN_GATED_WALL_S)``.  Benchmarks
    new in this run (absent from the baseline) are not gated.
    """
    problems = []
    for name, base in baseline.items():
        got = results.get(name)
        if got is None:
            problems.append(f"{name}: present in baseline but did not run")
            continue
        if got.get("status") != "ok":
            problems.append(f"{name}: status {got.get('status')!r} "
                            "(baseline: ok)")
            continue
        budget = tolerance * max(float(base.get("wall_s", 0.0)),
                                 MIN_GATED_WALL_S)
        if float(got.get("wall_s", 0.0)) > budget:
            problems.append(
                f"{name}: wall {got['wall_s']:.1f}s exceeds "
                f"{budget:.1f}s (baseline {base.get('wall_s', 0):.1f}s "
                f"x tolerance {tolerance})")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*", default=[],
                    help=f"benchmarks to run (default: all of {ALL})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, one rep: CI bit-rot gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-benchmark status + wall seconds as JSON")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="diff against a baseline JSON (BENCH_baseline.json) "
                         "and fail on regressions")
    ap.add_argument("--tolerance", type=float, default=4.0,
                    help="allowed wall-time ratio vs baseline (default 4.0; "
                         "generous on purpose — CI runners are noisy)")
    ap.add_argument("--retune", action="store_true",
                    help="re-run the KernelSpec autotuner for the host "
                         "platform before benchmarking (rewrites "
                         "--tune-baseline; see repro.kernels.autotune)")
    ap.add_argument("--tune-baseline", default="TUNE_baseline.json",
                    metavar="PATH",
                    help="tuning-cache file --retune rewrites (default "
                         "TUNE_baseline.json at the cwd/repo root)")
    args = ap.parse_args(argv)
    unknown = [n for n in args.names if n not in ALL]
    if unknown:
        ap.error(f"unknown benchmarks {unknown}; have {ALL}")
    names = args.names or ALL

    if args.retune:
        import os

        from repro.kernels import autotune
        print("===== retune =====")
        # point this process's spec resolution at the retuned file, so
        # the benchmarks below run against the fresh winners even when
        # --tune-baseline is a scratch copy (the CI drift check)
        os.environ[autotune.ENV_VAR] = str(args.tune_baseline)
        summary = autotune.retune(path=args.tune_baseline)
        print(f"===== retune done: {len(summary['entries'])} "
              f"{summary['platform']} entries "
              f"({summary['objective']}) =====")

    failures = []
    results = {}
    for name in names:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(smoke=args.smoke)
            wall = time.time() - t0
            results[name] = {"status": "ok", "wall_s": round(wall, 2)}
            print(f"===== {name} done in {wall:.1f}s =====")
        except Exception as e:  # keep the harness going, fail at exit
            failures.append(name)
            results[name] = {"status": "failed",
                             "wall_s": round(time.time() - t0, 2),
                             "error": f"{type(e).__name__}: {e}"}
            traceback.print_exc()
            print(f"===== {name} FAILED: {type(e).__name__}: {e} =====")

    if args.json:
        payload = {"smoke": bool(args.smoke), "benchmarks": results}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"\nwrote {args.json}")

    rc = 0
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        rc = 1
    if args.baseline:
        with open(args.baseline) as f:
            base_doc = json.load(f)
        baseline = base_doc.get("benchmarks", {})
        problems = compare_to_baseline(results, baseline, args.tolerance)
        if problems:
            print("\nBENCHMARK REGRESSIONS vs baseline:")
            for p in problems:
                print(f"  - {p}")
            rc = 1
        else:
            print(f"\nbenchmark gate OK vs {args.baseline} "
                  f"(tolerance {args.tolerance}x)")
        # A benchmark added in this PR has no baseline row, so
        # compare_to_baseline skipped it above — and, left alone, would
        # keep skipping it forever.  Fold new ok modules into the
        # artifact now so the *second* run gates them.  Failed modules
        # are never recorded, and neither is a mode mismatch: smoke and
        # full walls differ by orders of magnitude, so a smoke run must
        # not seed rows a full-mode gate would then compare against.
        new_ok = sorted(n for n, r in results.items()
                        if n not in baseline and r.get("status") == "ok")
        if new_ok and bool(base_doc.get("smoke")) == bool(args.smoke):
            for n in new_ok:
                baseline[n] = {"status": "ok",
                               "wall_s": results[n]["wall_s"]}
                print(f"recorded new benchmark {n!r} into {args.baseline} "
                      f"(wall {results[n]['wall_s']:.1f}s)")
            base_doc["benchmarks"] = baseline
            with open(args.baseline, "w") as f:
                json.dump(base_doc, f, indent=2, sort_keys=True)
                f.write("\n")
    return rc


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
