"""Serve-engine load benchmark: continuous batching vs fixed-slot lockstep.

Drives both engines over the same seeded Poisson arrival trace with
mixed prompt/output lengths and equal peak KV memory (the continuous
pool holds exactly ``n_slots x max_len`` tokens plus one scratch page),
reporting tokens/s and p50/p99 request latency.

The fixed-slot policy is the honest lockstep one: up to ``n_slots``
arrived requests batch together, decode ``max(out_len)`` steps (a
finished request burns its slot until the batch drains — extra tokens
are generated and discarded), and every request completes when its
batch does.  The continuous engine admits per slot, interleaves chunked
prefill with decode, recycles slots the moment a request finishes, and
streams per-request tokens.

``--smoke`` shrinks the trace and turns the run into a CI gate: the
continuous engine must sustain strictly higher tokens/s, its decode
step must have compiled exactly once, greedy outputs must match the
fixed-slot path per request, and the page free-list must drain clean.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core import backend as be
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousServeEngine


def make_trace(seed: int, n_requests: int, mean_interarrival_s: float,
               plen_lo: int, plen_hi: int, out_lens):
    """Poisson arrivals + mixed lengths. Returns a list of dicts."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_interarrival_s, n_requests))
    trace = []
    for i in range(n_requests):
        plen = int(rng.integers(plen_lo, plen_hi + 1))
        trace.append({
            "arrival_s": float(arrivals[i]),
            "prompt": [1 + int(t) for t in rng.integers(0, 300, plen)],
            "out_len": int(out_lens[i % len(out_lens)]),
        })
    return trace


def run_fixed(model, params, trace, n_slots: int, max_len: int,
              plen_hi: int):
    """Lockstep batches of arrived requests; completion = batch drain.

    Batches are padded to exactly ``n_slots`` prompts (idle slots run a
    dummy prompt — the fixed-slot engine computes them either way) and
    every prompt is left-padded to ``plen_hi``, so prefill and decode
    each compile once — the comparison measures scheduling policy, not
    XLA retraces.
    """
    eng = ServeEngine(model, params, ParallelCtx(), cache_n=max_len)
    dummy = [1] * plen_hi

    def pad(p):
        return [1] * (plen_hi - len(p)) + p

    eng.generate([dummy] * n_slots, max_new=2)  # warmup: compile both phases
    t0 = time.perf_counter()
    done_at = [0.0] * len(trace)
    outs = [None] * len(trace)
    nxt = 0
    while nxt < len(trace):
        now = time.perf_counter() - t0
        if trace[nxt]["arrival_s"] > now:  # engine idle: wait for arrivals
            time.sleep(trace[nxt]["arrival_s"] - now)
        now = time.perf_counter() - t0
        batch = [i for i in range(nxt, len(trace))
                 if trace[i]["arrival_s"] <= now][:n_slots]
        max_out = max(trace[i]["out_len"] for i in batch)
        prompts = [pad(trace[i]["prompt"]) for i in batch]
        prompts += [dummy] * (n_slots - len(prompts))
        res = eng.generate(prompts, max_new=max_out)
        end = time.perf_counter() - t0
        for j, i in enumerate(batch):
            outs[i] = res[j][:trace[i]["out_len"]]  # overshoot discarded
            done_at[i] = end
        nxt = batch[-1] + 1
    return outs, done_at, time.perf_counter() - t0


def run_continuous(model, params, trace, n_slots: int, max_len: int,
                   page_size: int, prefill_chunk: int):
    """Arrival-driven submission, streaming drain, per-request timing."""
    eng = ContinuousServeEngine(model, params, ParallelCtx(),
                                n_slots=n_slots, max_len=max_len,
                                page_size=page_size,
                                prefill_chunk=prefill_chunk)
    eng.generate([[1, 2]], max_new=2)  # warmup: compile both phases
    t0 = time.perf_counter()
    done_at = [0.0] * len(trace)
    outs = [[] for _ in trace]
    rid_to_i = {}
    nxt = 0
    while nxt < len(trace) or eng.pending:
        now = time.perf_counter() - t0
        if not eng.pending and nxt < len(trace) and \
                trace[nxt]["arrival_s"] > now:
            time.sleep(trace[nxt]["arrival_s"] - now)
            now = time.perf_counter() - t0
        while nxt < len(trace) and trace[nxt]["arrival_s"] <= now:
            rid = eng.submit(trace[nxt]["prompt"],
                             max_new=trace[nxt]["out_len"])
            rid_to_i[rid] = nxt
            nxt += 1
        for ev in eng.step():
            i = rid_to_i[ev.rid]
            if ev.token is not None:
                outs[i].append(ev.token)
            if ev.done:
                done_at[i] = time.perf_counter() - t0
    return eng, outs, done_at, time.perf_counter() - t0


def _report(label, trace, outs, done_at, wall_s):
    n_tok = sum(len(o) for o in outs)
    lat = np.asarray([done_at[i] - trace[i]["arrival_s"]
                      for i in range(len(trace))])
    tps = n_tok / wall_s
    print(f"{label:11s}: {n_tok:4d} tok in {wall_s:6.2f}s "
          f"({tps:7.1f} tok/s)  latency p50 {np.percentile(lat, 50)*1e3:7.1f}ms"
          f"  p99 {np.percentile(lat, 99)*1e3:7.1f}ms")
    return tps


def main(smoke: bool = False):
    bk = be.resolve_backend_name(None)
    # interpret mode is a correctness path with per-op python dispatch —
    # shrink the trace the way fused_div does so the gate stays fast
    slow = bk == "pallas-interpret"
    # skewed output lengths: one long straggler per n_slots requests —
    # the regime continuous batching exists for (lockstep burns
    # max(out_len) steps per batch; slot recycling doesn't)
    n_requests = 12 if slow else (16 if smoke else 48)
    out_lens = ((2, 2, 2, 40) if slow else (2, 2, 2, 50)) if smoke \
        else (4, 8, 6, 48, 12, 8)
    n_slots, max_len, page_size, chunk = \
        (4, 64, 8, 16) if smoke else (8, 128, 16, 32)
    cfg = get_config("minicpm_2b").reduced().with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plen_hi = min(12, max_len // 2)
    trace = make_trace(seed=0, n_requests=n_requests,
                       mean_interarrival_s=0.002, plen_lo=2,
                       plen_hi=plen_hi, out_lens=out_lens)

    kv_tokens_fixed = n_slots * max_len
    # each runner warms its engine (compiles both phases) before starting
    # its clock, so the walls compare steady-state scheduling policy
    fx_outs, fx_done, fx_wall = run_fixed(model, params, trace, n_slots,
                                          max_len, plen_hi)
    eng, ct_outs, ct_done, ct_wall = run_continuous(
        model, params, trace, n_slots, max_len, page_size, chunk)
    kv_tokens_cont = eng.geom.usable_pages * eng.geom.page_size

    print(f"backend={bk}  n_slots={n_slots}  peak KV tokens: "
          f"fixed={kv_tokens_fixed} continuous={kv_tokens_cont} "
          f"(+1 scratch page)")
    fx_tps = _report("fixed-slot", trace, fx_outs, fx_done, fx_wall)
    ct_tps = _report("continuous", trace, ct_outs, ct_done, ct_wall)
    print(f"continuous/fixed tokens/s: {ct_tps / fx_tps:.2f}x   "
          f"decode compiles: {eng.trace_counts['decode']}")

    if smoke:
        assert kv_tokens_cont == kv_tokens_fixed, \
            f"KV memory mismatch: {kv_tokens_cont} != {kv_tokens_fixed}"
        assert eng.trace_counts["decode"] == 1, \
            f"decode recompiled: {eng.trace_counts['decode']} traces"
        assert eng.alloc.n_free == eng.geom.usable_pages, "page leak"
        # greedy parity per request against the fixed-slot path (B=1 —
        # the lockstep batch left-pads, so per-request is the reference)
        ref_eng = ServeEngine(model, params, ParallelCtx(), cache_n=max_len)
        for i in (0, 1, len(trace) - 1):
            ref = ref_eng.generate([trace[i]["prompt"]],
                                   max_new=trace[i]["out_len"])[0]
            assert ct_outs[i] == ref, \
                f"request {i}: continuous {ct_outs[i]} != fixed {ref}"
        assert ct_tps > fx_tps, \
            f"continuous {ct_tps:.1f} tok/s not faster than fixed " \
            f"{fx_tps:.1f} tok/s"
        print("smoke asserts OK: equal KV, one decode compile, no leak, "
              "greedy parity, higher tokens/s")


if __name__ == "__main__":
    main()
