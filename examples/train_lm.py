"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps on CPU, exact vs RAPID arithmetic, with checkpoints.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--approx]
(~100M params: 12 layers x d_model 512 over a 32k vocab.)
"""
import argparse

import jax

from repro.configs.base import RAPID, get_config
from repro.data.pipeline import SyntheticLM
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.models.params import count_params
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig
from repro.train.trainstep import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--approx", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config("yi_6b").with_(
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1408, vocab_size=32000, scan_layers=True, remat="none",
        dtype="float32",
    )
    if args.approx:
        cfg = cfg.with_(approx=RAPID)
    model = Model(cfg)
    n = count_params(model.param_specs())
    print(f"model: {n/1e6:.1f}M params, approx={'RAPID' if args.approx else 'exact'}")

    ctx = ParallelCtx()
    params = model.init(jax.random.PRNGKey(0))
    oc = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    init_opt, train_step = make_train_step(model, oc, ctx)
    opt = init_opt(params)
    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    lc = LoopConfig(total_steps=args.steps, ckpt_every=100, log_every=20,
                    ckpt_dir="/tmp/repro_train_lm")
    state = train_loop(jax.jit(train_step, donate_argnums=(0, 1)),
                       params, opt, src, lc)
    print(f"loss: {state.losses[0]:.3f} -> {state.losses[-1]:.3f} "
          f"({state.step} steps)")


if __name__ == "__main__":
    main()
