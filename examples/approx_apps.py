"""The paper's three applications end-to-end (JPEG / Pan-Tompkins QRS /
Harris corners) under every arithmetic variant.

Run: PYTHONPATH=src python examples/approx_apps.py
"""
from repro.apps import harris, jpeg, pan_tompkins


def main():
    print("== JPEG compression (PSNR dB; paper Fig. 8: 30.9 acc / 28.7 rapid"
          " / 24.4 truncated) ==")
    for k, v in jpeg.run(n_images=2, size=192).items():
        print(f"  {k:10s} {v:6.2f} dB")
    print("\n== Pan-Tompkins QRS detection (paper: ~100% detection,"
          " >=28 dB) ==")
    for k, v in pan_tompkins.run(n_beats=30).items():
        print(f"  {k:10s} sens={v['sensitivity']:.3f} ppv={v['ppv']:.3f} "
              f"psnr={v['psnr_vs_accurate_db']} dB")
    print("\n== Harris corner tracking (correct vectors %; paper Fig. 9:"
          " 100/94/83) ==")
    for k, v in harris.run(n_images=2, size=160).items():
        print(f"  {k:10s} {v:5.1f}%")


if __name__ == "__main__":
    main()
