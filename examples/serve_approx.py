"""Serve a small model with batched requests, comparing exact vs RAPID
decode outputs (token agreement + throughput).

Run: PYTHONPATH=src python examples/serve_approx.py
"""
import time

import jax

from repro.configs.base import RAPID, get_config
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.serve.engine import ServeEngine


def main():
    base = get_config("minicpm_2b").reduced().with_(dtype="float32")
    prompts = [[1 + (7 * i + j) % 300 for j in range(6 + i % 3)]
               for i in range(8)]
    outs = {}
    for mode in ("exact", "rapid"):
        cfg = base if mode == "exact" else base.with_(approx=RAPID)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, ParallelCtx(), cache_n=64)
        t0 = time.time()
        outs[mode] = eng.generate(prompts, max_new=12)
        dt = time.time() - t0
        n = sum(len(o) for o in outs[mode])
        print(f"{mode:6s}: {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")
    agree = sum(
        a == b for oa, ob in zip(outs["exact"], outs["rapid"])
        for a, b in zip(oa, ob))
    total = sum(len(o) for o in outs["exact"])
    print(f"token agreement exact-vs-rapid: {agree}/{total} "
          f"({100*agree/total:.0f}%) — untrained weights amplify "
          "arithmetic differences; trained models agree far more")


if __name__ == "__main__":
    main()
