"""Serve a small model with continuous batching, comparing exact vs
RAPID decode outputs (token agreement) under a Poisson arrival trace
(per-request streaming, tokens/s, p50/p99 latency).

Run: PYTHONPATH=src python examples/serve_approx.py

The engine (repro.serve.scheduler.ContinuousServeEngine) admits
requests into slots as they arrive, interleaves chunked prefill with
decode ticks, stores KV in a block-paged pool, and streams each
request's tokens back as StreamEvents the moment they are sampled —
see benchmarks/serve_load.py for the head-to-head against the
fixed-slot lockstep engine.
"""
import time

import jax
import numpy as np

from repro.configs.base import RAPID, get_config
from repro.models.model import Model
from repro.serve.scheduler import ContinuousServeEngine


def make_trace(seed=0, n_requests=8, mean_interarrival_s=0.02):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_interarrival_s, n_requests))
    return [{
        "arrival_s": float(arrivals[i]),
        "prompt": [1 + int(t) for t in rng.integers(0, 300, 6 + i % 5)],
        "out_len": int((4, 4, 4, 24)[i % 4]),  # one straggler per 4
    } for i in range(n_requests)]


def serve(model, params, trace):
    """Arrival-driven loop: submit on arrival, stream until drained."""
    eng = ContinuousServeEngine(model, params, n_slots=4, max_len=64,
                                page_size=8, prefill_chunk=16)
    eng.generate([[1, 2]], max_new=2)  # warmup: compile both phases
    t0 = time.perf_counter()
    outs, done_at, rid_to_i, nxt = [[] for _ in trace], [0.0] * len(trace), \
        {}, 0
    while nxt < len(trace) or eng.pending:
        now = time.perf_counter() - t0
        while nxt < len(trace) and trace[nxt]["arrival_s"] <= now:
            rid = eng.submit(trace[nxt]["prompt"],
                             max_new=trace[nxt]["out_len"])
            rid_to_i[rid] = nxt
            nxt += 1
        for ev in eng.step():  # one admit + prefill-chunk + decode tick
            if ev.token is not None:
                outs[rid_to_i[ev.rid]].append(ev.token)
            if ev.done:
                done_at[rid_to_i[ev.rid]] = time.perf_counter() - t0
    wall = time.perf_counter() - t0
    lat = [done_at[i] - trace[i]["arrival_s"] for i in range(len(trace))]
    return outs, wall, lat


def main():
    base = get_config("minicpm_2b").reduced().with_(dtype="float32")
    trace = make_trace()
    outs = {}
    for mode in ("exact", "rapid"):
        cfg = base if mode == "exact" else base.with_(approx=RAPID)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        outs[mode], wall, lat = serve(model, params, trace)
        n = sum(len(o) for o in outs[mode])
        print(f"{mode:6s}: {n} tokens in {wall:.2f}s ({n/wall:.1f} tok/s)  "
              f"latency p50 {np.percentile(lat, 50)*1e3:.0f}ms  "
              f"p99 {np.percentile(lat, 99)*1e3:.0f}ms")
    agree = sum(
        a == b for oa, ob in zip(outs["exact"], outs["rapid"])
        for a, b in zip(oa, ob))
    total = sum(len(o) for o in outs["exact"])
    print(f"token agreement exact-vs-rapid: {agree}/{total} "
          f"({100*agree/total:.0f}%) — untrained weights amplify "
          "arithmetic differences; trained models agree far more")


if __name__ == "__main__":
    main()
