"""Quickstart: RAPID approximate arithmetic in 30 lines.

Run: PYTHONPATH=src python examples/quickstart.py

CI (.github/workflows/ci.yml) gates every PR — badge:
https://github.com/<org>/<repo>/actions/workflows/ci.yml/badge.svg

  job          what it proves
  -----------  ------------------------------------------------------
  lint         ruff correctness rules (ruff.toml) + compileall
  tier1        full suite on jax 0.4.37 *and* 0.8.0 (compat shim
               exercised both ways)
  parity       jnp oracle vs pallas-interpret bit-exactness sweep
  multidevice  EP/TP shard_map tests on 8 fake XLA devices, both jax
               pins — the kernels really run on local shards
  bench-gate   benchmarks.run --retune --smoke + regression diff
               against the committed BENCH_baseline.json, and a drift
               check that the retune reproduced TUNE_baseline.json
               byte-for-byte (JSON uploaded as a PR artifact);
               serve_load additionally asserts continuous batching
               beats fixed-slot tokens/s at equal KV memory
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.float_approx import approx_div, approx_mul
from repro.core.ops import qmatmul

# --- elementwise: the paper's multiplier/divider on floats -------------
a = jnp.asarray([3.0, 58.0, -7.5], jnp.float32)
b = jnp.asarray([4.0, 18.0, 2.5], jnp.float32)
print("exact   mul:", np.asarray(a * b))
print("mitchell mul:", np.asarray(approx_mul(a, b, "mitchell")))
print("rapid10  mul:", np.asarray(approx_mul(a, b, "rapid10")))
print("rapid9   div:", np.asarray(approx_div(a, b, "rapid9")),
      "(exact:", np.asarray(a / b), ")")

# --- matmul through the logarithmic multiplier --------------------------
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
w = jnp.asarray(rng.normal(size=(128, 32)), jnp.float32)
exact = x @ w
approx = qmatmul(x, w, "rapid10")
rel = float(jnp.abs(approx - exact).mean() / jnp.abs(exact).mean())
print(f"\nmatmul rel-L1 error (rapid10): {rel:.4%}  "
      "(near-zero bias -> errors cancel in dot products)")

# --- it differentiates: straight-through gradients ----------------------
g = jax.grad(lambda x: qmatmul(x, w, "rapid10").sum())(x)
print("grad shape:", g.shape, "finite:", bool(jnp.isfinite(g).all()))

# --- backend selection & epilogues --------------------------------------
# Every approximate op routes through the backend registry
# (repro.core.backend): "jnp" (partitioner-visible oracle), "pallas"
# (TPU kernels) or "pallas-interpret" (kernels on CPU, for parity
# checks).  Selection precedence at any call site:
#   backend= argument > $RAPID_BACKEND env var > process default
#   (backend.set_default_backend) > hardware autodetect.
# Model configs carry a *per-site* map instead of one global name, so a
# single model can mix execution paths:
#   cfg.with_site_backends({"mlp": "pallas", "logits": "jnp"})
# (sites: mlp / attn_proj / logits / norm / softmax / default; the
# launchers expose the same via --backend / --site-backend SITE=NAME).
from repro.core.backend import Epilogue, resolve_backend_name

print("\nresolved backend:", resolve_backend_name(None))

# The epilogue menu fuses a whole block tail into the matmul's output
# tile: norm(activation(x @ w + bias) + residual) in one VMEM-resident
# pass, with the normalization divide running through the RAPID divider.
bias = jnp.zeros((32,), jnp.float32)
residual = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
tail, res_stream = qmatmul(
    x, w, "rapid10",
    bias=bias,
    residual=residual,
    epilogue=Epilogue(activation="silu", norm="rms", div_scheme="rapid9",
                      keep_prenorm=True),  # also emit the pre-norm value
)
print("fused block tail:", tail.shape, "residual stream:", res_stream.shape)

# --- KernelSpec: one spec object, and the pipeline-depth knob -----------
# Every Pallas kernel family (log_matmul, the fused_div variants,
# rapid_mul / rapid_div elementwise, flash-decode attention) accepts the
# same spec object instead of per-family positional tuples.
#
# Migration notes (removed APIs):
#   * `log_matmul(..., blocks=(bm, bn, bk))` and tuple specs are gone —
#     passing `blocks=` raises TypeError; write
#     `spec=KernelSpec(bm=..., bn=..., bk=...)` instead.
#   * The deprecated `ApproxConfig.backend` / `.matmul_backend` read
#     aliases are gone — reads raise AttributeError; use
#     `cfg.backend_for(site)` (lint rule RPD009 hard-errors on any
#     source site, and is not baselineable).
from repro.kernels.log_matmul.ops import log_matmul
from repro.kernels.spec import KernelSpec, PipelineSpec

spec = KernelSpec(bm=8, bn=128, bk=128,            # tile geometry (None: auto)
                  pipeline=PipelineSpec(depth=2))  # in-flight copy stages
# depth=1 lowers the classic grid formulation; depth>=2 emits a manual
# async-copy pipeline (HBM-resident operands, `depth` VMEM tile buffers
# rotating behind DMA semaphores).  The knob is schedule-only — every
# depth is bit-exact against the jnp oracle (tests/test_parity_sweep.py)
# — and the kernel auditor re-checks the VMEM working set *at the
# requested depth* (PIPELINE_REPORT.json: pipeline_depth/scratch_bytes).
y1 = log_matmul(x, w, "rapid10", interpret=True,
                spec=KernelSpec(pipeline=PipelineSpec(depth=1)))
y2 = log_matmul(x, w, "rapid10", interpret=True, spec=spec)
print("\ndepth 1 vs depth 2 bit-identical:", bool((y1 == y2).all()))
# benchmarks/roofline.py times the depth-1 vs depth-2 schedules and the
# fused flash-attention kernel vs the separate-passes path on a shared
# arithmetic-intensity axis.

# --- autotuning kernel specs --------------------------------------------
# Fields you leave as None are filled by resolve_spec (kernels/spec.py),
# the single choke point every wrapper and core/backend.py dispatcher
# goes through.  Per-field precedence:
#
#   explicit KernelSpec field  >  tuning-cache hit  >  heuristic
#
# The tuning cache is TUNE_baseline.json at the repo root (override with
# $RAPID_TUNE_CACHE): committed, versioned winners produced by the
# autotuner in repro.kernels.autotune, which times every budget-legal
# (bm, bn, bk, depth) candidate per kernel family on the actual device
# — real wall time on TPU, a deterministic static cost model elsewhere,
# so CI can regenerate the file byte-for-byte.  Entries are keyed by
# (family, bucketed shape class, scheme, epilogue kind, platform), so
# nearby dispatch shapes that tile identically share a winner.  Every
# knob the tuner searches is schedule-only: a cached spec stays
# bit-exact against the jnp oracle (tests/test_autotune.py proves it
# for every committed entry).
#
#   PYTHONPATH=src python -m benchmarks.run --retune   # re-search + save
#   PYTHONPATH=src python -m repro.kernels.autotune --list  # inspect
#
# Pin a spec manually when you want to override the cache at one call
# site — an explicit field always wins:
from repro.kernels.spec import resolve_spec

auto = resolve_spec("log_matmul", (512, 512, 512), scheme="rapid10")
pinned = resolve_spec("log_matmul", (512, 512, 512),
                      KernelSpec(bm=64), scheme="rapid10")
print("tuned 512^3 spec:", (auto.bm, auto.bn, auto.bk, auto.depth),
      "| pinned bm wins:", pinned.bm)

# --- running sharded with the pallas backend ----------------------------
# The pallas kernels are *per-device*, so on a multi-device process the
# hardware autodetect answers per call site: pjit-visible (global-view)
# matmuls resolve to the partitionable "jnp" formulation, while code
# traced inside a `repro.compat.shard_map` body — the EP/TP expert
# compute in models/moe.py, the flash-decode combine — sees per-shard
# shapes and legally runs the kernels on each local shard.  Engines and
# train steps pin per-site backends at build (core.backend.pin_backends);
# on a multi-device TPU an auto site pins as the AUTO_HW sentinel, which
# re-resolves only from the memoized hardware probe + the trace context,
# so the same pinned config routes jnp under pjit and pallas under
# shard_map, and post-build env changes can't flip compiled kernels.
#
#   from repro.parallel.sharding import make_rules
#   mesh = jax.make_mesh((2, 4), ("data", "model"))   # EP over "model"
#   ctx = ParallelCtx(mesh, make_rules(cfg))
#   out = jax.jit(lambda x, p: moe_ffn(x, p, cfg, ctx))(x, params)
#
# Locality is detected from the axis environment (works on jax 0.4.x and
# 0.8+); shard_map bodies must run under jit — the eager shard_map
# interpreter has no pallas rule.  CI's `multidevice` job forces an
# 8-device CPU host (XLA_FLAGS=--xla_force_host_platform_device_count=8)
# and checks the sharded EP/TP forward bit-exact against the
# single-device oracle (tests/test_shardmap_parity.py).
from repro import compat

print("\nin shard_map?", compat.in_shard_map(),
      "| axis env:", compat.axis_env_sizes())

# --- continuous-batching serve ------------------------------------------
# Two serving engines live in repro.serve:
#   * ServeEngine (engine.py): fixed-slot lockstep — one dense KV cache
#     of cache_n tokens per slot, the whole batch prefills together and
#     decodes until the *longest* request finishes.
#   * ContinuousServeEngine (scheduler.py): a request queue with
#     per-slot admission, KV in a block-paged pool (paged_kv.py) so
#     memory scales with live tokens, chunked prefill interleaved with
#     decode ticks, slot recycling the moment a request completes, and
#     per-request streaming.  Decode runs one compiled step with fixed
#     [n_slots, 1] shapes and a dynamic occupancy mask, so mid-flight
#     admissions/evictions never retrace.  Greedy outputs are
#     bit-identical to the fixed-slot engine per request.
# benchmarks/serve_load.py races the two under a Poisson arrival trace
# at equal peak KV memory; CI asserts continuous wins tokens/s.
from repro.configs.base import get_config
from repro.models.model import Model
from repro.serve.scheduler import ContinuousServeEngine

cfg = get_config("minicpm_2b").reduced().with_(dtype="float32")
model = Model(cfg)
eng = ContinuousServeEngine(model, model.init(jax.random.PRNGKey(0)),
                            n_slots=2, max_len=32, page_size=8,
                            prefill_chunk=8)
print("\nstreaming 3 requests through 2 slots:")
for ev in eng.stream([[5, 6, 7], [8, 9], [10, 11, 12, 13]], max_new=4):
    print(f"  rid={ev.rid} token={ev.token} done={ev.done}")
print("decode compiled", eng.trace_counts["decode"], "time(s); pages free:",
      eng.alloc.n_free, "/", eng.geom.usable_pages)

# --- auditing approximate-dispatch coverage ------------------------------
# The paper's end-to-end numbers assume the approximate units replaced
# *every* multiply/divide in the datapath — one raw `/` or `@` silently
# reverts a site to exact arithmetic.  repro.analysis proves coverage
# in three layers:
#
#   PYTHONPATH=src python -m repro.analysis.lint          # layer 1 (fast)
#   PYTHONPATH=src python -m repro.analysis.jaxpr_audit   # layer 2 (traces)
#   PYTHONPATH=src python -m repro.analysis.kernel_audit  # layer 3 (geometry)
#   PYTHONPATH=src python -m repro.analysis \
#       --baseline AUDIT_baseline.json --json report.json   # all + ratchet
#
# Layer 1 is an AST lint (rules RPD001-RPD004: raw matmul/div in
# models/apps/serve/train, LUT re-baking under jit, literal backend
# strings — `python -m repro.analysis.lint --list-rules`).  Layer 2
# traces every entry point (forward, decode, paged decode, trainstep,
# each app) and censuses the jaxpr: registry-dispatched ops are
# log-domain (bitcast + integer add + LUT gather) and so emit ZERO
# dot_general/div primitives — any such primitive whose innermost user
# frame is outside core/+kernels/ is an escape.  It also flags retrace
# hazards (unhashable config leaves) and duplicated baked-in LUTs.
#
# A genuinely-exact site is declared, with a mandatory reason (inline,
# or as the LAST comment line directly above the statement):
#
#     return acc / l[..., None]  # audit: exact — the exact-softmax arm
#
# --- kernel geometry audit (layer 3) -------------------------------------
# Layers 1+2 prove mul/div *route through* the registry; layer 3 proves
# the Pallas kernels the registry dispatches are geometrically legal
# before they touch a TPU.  A capture shim (repro.analysis.capture)
# monkeypatches pl.pallas_call under jax.disable_jit(), drives every
# registered kernel family (log_matmul, the fused_div variants,
# rapid_mul/rapid_div) through its public wrapper across the bench
# shape classes, and checks each captured grid/BlockSpec/index-map:
#
#   RPD005  per-grid-step VMEM working set (double-buffered) vs the
#           explicit budget in repro.kernels.budget — the same
#           constants resolve_spec's heuristics derive block sizes
#           from (and the autotuner's candidate filter enforces)
#   RPD006  lane (%128) / sublane (%8) alignment, blocks divide the
#           padded dims
#   RPD007  index maps surjective onto the block grid (a non-surjective
#           map silently drops elements) + every registry family has an
#           audited variant
#   RPD008  output tiles revisited across a grid dim must accumulate or
#           guard with pl.when(program_id == first/last), never on a
#           "parallel" dim
#
#   PYTHONPATH=src python -m repro.analysis.kernel_audit --list-variants
#   PYTHONPATH=src python -m repro.analysis.kernel_audit \
#       --report PIPELINE_REPORT.json
#
# The committed PIPELINE_REPORT.json records per-variant pipeline
# legality (grid, semantics, working set, revisit discipline,
# double_buffer_safe) — the contract the software-pipelining work must
# preserve.
#
# Everything else lives in AUDIT_baseline.json: a *ratchet* — new
# findings in any layer fail CI (the `audit` job, on both jax pins),
# known ones are allowlisted for burn-down, entries you fixed warn as
# stale (CI passes --fail-stale, so fix means shrink the baseline).
# After an intentional change, regenerate with
# `PYTHONPATH=src python -m repro.analysis --json AUDIT_baseline.json`
# (or drop fixed entries in place with `--baseline AUDIT_baseline.json
# --prune-stale`) and review the diff like code.  Operators get the
# same thing plus an optional compiled-HLO cross-check via
# `python -m repro.launch.audit --hlo dumped.txt`.
from repro.analysis import RULES
from repro.core.backend import dispatch_signature, registered_sites

print("\naudit rules:", ", ".join(sorted(RULES)))
print("dispatch sites:", registered_sites())
print("jnp backend div family ->", dispatch_signature("jnp")["div"])
