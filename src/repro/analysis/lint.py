"""Layer-1 CLI: AST lint over ``src/repro``.

    PYTHONPATH=src python -m repro.analysis.lint [--root src/repro]
        [--json out.json] [--baseline AUDIT_baseline.json] [--list-rules]

Without ``--baseline`` every finding is printed and a nonzero count
exits 1 (useful while burning the allowlist down to zero).  With
``--baseline`` the ratchet applies: allowlisted findings pass, new ones
fail with ``file:line``.  The combined two-layer runner
(``python -m repro.analysis``) is what CI uses; this entry point exists
for fast local iteration (no jax import, runs in milliseconds).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis import findings as F
from repro.analysis import rules


def _default_root() -> Path:
    # the package dir this module lives in: .../src/repro
    return Path(__file__).resolve().parent.parent


def run_lint(root: Optional[Path] = None) -> List[F.Finding]:
    return rules.collect(root or _default_root())


def print_findings(items: List[F.Finding], stream=sys.stdout) -> None:
    for f in sorted(items, key=lambda f: (f.file, f.line, f.rule)):
        stream.write(f"{f.where()}: {f.rule} {f.msg}\n")
        if f.code:
            stream.write(f"    {f.code}\n")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="RAPID dispatch-coverage AST lint (RPD rules)")
    ap.add_argument("--root", type=Path, default=None,
                    help="package dir to lint (default: the installed "
                         "repro package)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write findings as a JSON report")
    ap.add_argument("--baseline", default="", metavar="PATH",
                    help="ratchet against a committed baseline instead of "
                         "failing on any finding")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in rules.RULES.items():
            print(f"{rule}  {desc}")
        # layer-3 kernel-geometry rules (checked by repro.analysis.
        # kernel_audit over captured pallas_call geometry, not source)
        for rule, desc in rules.KERNEL_RULES.items():
            print(f"{rule}  {desc}  [kernel layer]")
        return 0

    found = run_lint(args.root)
    result: Optional[F.CompareResult] = None
    if args.baseline:
        # hard-error rules are non-baselineable: drop any committed
        # baseline entry for them so an occurrence always reads as new
        baseline = [f for f in F.load_baseline(args.baseline)
                    if f.layer == "ast"
                    and f.rule not in rules.HARD_ERROR_RULES]
        result = F.compare(found, baseline)
        print_findings(result.new)
        for w in result.warnings:
            print(f"warning: {w}")
        print(f"lint ratchet: {result.summary()}")
        ok = result.ok
    else:
        print_findings(found)
        print(f"{len(found)} finding(s)")
        ok = not found

    if args.json:
        F.dump_report(args.json, found, [], result=result)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
