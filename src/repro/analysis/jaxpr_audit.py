"""Layer-2 audit: trace entry points and census registry escapes.

The AST lint (layer 1) reads source; this layer reads what jax will
actually execute.  Key fact the census exploits: the approximate
registry ops are *log-domain* — bitcast + integer add + LUT gather —
so a registry-dispatched multiply/divide contains **zero**
``dot_general`` / ``div`` primitives.  Every ``dot_general``/``div``
equation left in a traced entry point is therefore either

  * **accounted** — its innermost user frame sits under
    ``repro/core/`` or ``repro/kernels/`` (the declared-exact qmatmul
    path, ``exact_einsum``, the kernels' oracles), or
  * an **escape** — exact arithmetic reached from model/app/serve/train
    code without going through the registry, reported per
    ``(entry, primitive, file)`` and ratcheted against
    ``AUDIT_baseline.json``.

On top of the census the auditor flags two trace-hygiene hazards:

  * **retrace hazards** — unhashable leaves inside an entry's static
    config (a config that cannot ride jit static args silently retraces
    per call);
  * **duplicated large constants** — two identical >=256-element consts
    baked into one closed jaxpr (the signature of a LUT rebuilt per call
    site instead of the memoized ``mitchell.lut_host`` table).

Run ``python -m repro.analysis.jaxpr_audit`` (slow: traces every entry)
or the combined ``python -m repro.analysis``.
"""
from __future__ import annotations

import argparse
import functools
import hashlib
import sys
import sysconfig
from collections import OrderedDict
from dataclasses import fields as dataclass_fields, is_dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.findings import UNATTRIBUTED, CompareResult, Finding
from repro.analysis import findings as F

__all__ = [
    "AUDITED_PRIMITIVES",
    "ACCOUNTED_PREFIXES",
    "ENTRIES",
    "iter_eqns",
    "audit_fn",
    "run_audit",
    "duplicate_consts",
    "unhashable_leaves",
]

#: primitives that must not appear outside registry-accounted frames
AUDITED_PRIMITIVES = ("dot_general", "div")

#: repo-relative prefixes whose dot/div eqns are registry-accounted
ACCOUNTED_PREFIXES = ("src/repro/core/", "src/repro/kernels/")

_DUP_CONST_MIN_SIZE = 256


# --------------------------------------------------------------------------
# jaxpr walking (shared idiom with launch/hlo_analysis: one flat iterator
# over nested instruction containers)
# --------------------------------------------------------------------------

def _sub_jaxprs(params: dict):
    """Inner jaxprs hiding in an eqn's params (pjit/scan/custom_vjp/...).

    Duck-typed: ``isinstance`` against ``jax.core.Jaxpr`` misses
    reexported/closed variants across jax versions, but every container
    either has ``.eqns`` (a Jaxpr) or wraps one as ``.jaxpr``
    (a ClosedJaxpr).
    """
    for v in params.values():
        items = v if isinstance(v, (list, tuple)) else (v,)
        for x in items:
            if hasattr(x, "eqns"):
                yield x
            elif hasattr(x, "jaxpr") and hasattr(x.jaxpr, "eqns"):
                yield x.jaxpr


def iter_eqns(jaxpr):
    """Depth-first over every eqn, descending into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


# --------------------------------------------------------------------------
# source attribution
# --------------------------------------------------------------------------

_STDLIB = sysconfig.get_paths().get("stdlib", "") or "\x00"


def _is_user_file(fname: str) -> bool:
    if not fname:
        return False
    if "site-packages" in fname or "/jax/" in fname or "/jaxlib/" in fname:
        return False
    if fname.startswith(_STDLIB):
        return False
    for prefix in (sys.prefix, sys.base_prefix):
        if prefix and fname.startswith(prefix) and "repro" not in fname:
            return False
    return True


def _frame_file_line(fr) -> Tuple[str, int]:
    fname = getattr(fr, "file_name", None) or getattr(fr, "filename", "") or ""
    line = (getattr(fr, "start_line", None) or getattr(fr, "line_num", None)
            or getattr(fr, "lineno", None) or 0)
    return fname, int(line)


def _eqn_frames(eqn):
    """User frames for an eqn, innermost first; [] if source info is gone."""
    si = getattr(eqn, "source_info", None)
    if si is None:
        return []
    frames = None
    try:
        from jax._src import source_info_util as siu
        frames = list(siu.user_frames(si))
    except Exception:
        tb = getattr(si, "traceback", None)
        frames = list(getattr(tb, "frames", ()) or ()) if tb is not None else []
    out = []
    for fr in frames:
        fname, line = _frame_file_line(fr)
        if _is_user_file(fname):
            out.append((fname, line))
    return out


def _rel_repro(fname: str) -> Optional[str]:
    """Absolute frame path -> committed-baseline path (src/repro/...)."""
    parts = Path(fname).parts
    if "repro" in parts:
        i = parts.index("repro")
        return "/".join(("src",) + parts[i:])
    # non-package user code (tests, scripts): best-effort basename anchor
    for anchor in ("tests", "benchmarks", "examples"):
        if anchor in parts:
            return "/".join(parts[parts.index(anchor):])
    return None


def attribute_eqn(eqn) -> Tuple[str, int]:
    """(repo-relative file, line) of an eqn's innermost user frame."""
    for fname, line in _eqn_frames(eqn):
        rel = _rel_repro(fname)
        if rel is not None:
            return rel, line
    return UNATTRIBUTED, 0


# --------------------------------------------------------------------------
# escape census + hazards for one traced function
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _markers_for(rel_file: str) -> dict:
    """line -> '# audit: exact' reason for one committed source file.

    The jaxpr layer honors the same marker contract as the AST lint: a
    dot/div whose attributed line carries a reasoned marker is declared
    exact, not an escape.  Resolved against the repo this package runs
    from; unreadable files (installed wheel, moved tree) yield {}.
    """
    from repro.analysis.rules import _marker_lines

    root = Path(__file__).resolve().parents[3]
    try:
        source = (root / rel_file).read_text()
    except OSError:
        return {}
    return {ln: r for ln, r in _marker_lines(source).items() if r}


def census(closed, entry: str) -> List[Finding]:
    """Escape findings for one closed jaxpr (aggregated per key)."""
    hits: "OrderedDict[Tuple[str, str], List[int]]" = OrderedDict()
    for eqn in iter_eqns(closed.jaxpr):
        prim = eqn.primitive.name
        if prim not in AUDITED_PRIMITIVES:
            continue
        file, line = attribute_eqn(eqn)
        if file != UNATTRIBUTED:
            if file.startswith(ACCOUNTED_PREFIXES):
                continue
            if line in _markers_for(file):
                continue  # declared '# audit: exact — reason' at the site
        key = (prim, file)
        if key in hits:
            hits[key][0] += 1
        else:
            hits[key] = [1, line]
    out = []
    for (prim, file), (count, line) in hits.items():
        out.append(Finding(
            layer="jaxpr", rule="escape", file=file, line=line,
            msg=f"{prim} outside registry-accounted frames "
                f"(x{count} in entry {entry!r})",
            entry=entry, primitive=prim, count=count))
    return out


def duplicate_consts(closed, min_size: int = _DUP_CONST_MIN_SIZE
                     ) -> List[str]:
    """Identical large consts baked in twice (per-call-site LUT rebuild)."""
    seen: Dict[Tuple[str, tuple, str], int] = {}
    for c in closed.consts:
        try:
            arr = np.asarray(c)
        except Exception:
            continue
        if arr.size < min_size:
            continue
        key = (str(arr.dtype), tuple(arr.shape),
               hashlib.sha1(arr.tobytes()).hexdigest())
        seen[key] = seen.get(key, 0) + 1
    return [f"const {shape} {dtype} baked in {n}x (duplicated LUT? "
            f"hoist through mitchell.lut_host/lut_device)"
            for (dtype, shape, _), n in seen.items() if n > 1]


def unhashable_leaves(obj, path: str = "cfg") -> List[str]:
    """Paths of unhashable leaves in a static-config object tree.

    An entry's config rides jit static args / custom_vjp nondiff
    positions; one unhashable leaf means silent retrace-per-call.
    """
    try:
        hash(obj)
        return []
    except TypeError:
        pass
    out: List[str] = []
    if is_dataclass(obj) and not isinstance(obj, type):
        for f in dataclass_fields(obj):
            out += unhashable_leaves(getattr(obj, f.name), f"{path}.{f.name}")
    elif isinstance(obj, dict):
        for k, v in obj.items():
            out += unhashable_leaves(v, f"{path}[{k!r}]")
    elif isinstance(obj, (list, tuple, set)):
        for i, v in enumerate(obj):
            out += unhashable_leaves(v, f"{path}[{i}]")
    else:
        out.append(f"{path}: unhashable {type(obj).__name__}")
    # a container whose members all hash individually is itself the leaf
    # (e.g. a dict: members fine, dict not) — report the container once
    return out or [f"{path}: unhashable {type(obj).__name__}"]


def audit_fn(fn: Callable, args: tuple, entry: str,
             static_config=None) -> Tuple[List[Finding], dict]:
    """Trace ``fn(*args)`` and return (escape findings, meta dict)."""
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    findings = census(closed, entry)
    n_audited = sum(1 for e in iter_eqns(closed.jaxpr)
                    if e.primitive.name in AUDITED_PRIMITIVES)
    meta = {
        "eqns_audited": n_audited,
        "escapes": int(sum(f.count for f in findings)),
        "dup_consts": duplicate_consts(closed),
        "retrace_hazards": (unhashable_leaves(static_config)
                            if static_config is not None else []),
    }
    return findings, meta


# --------------------------------------------------------------------------
# entry-point registry: name -> builder returning (fn, args, static_cfg).
# Builders run on CPU with reduced configs; tracing is abstract so the
# concrete argument values never matter, only shapes/dtypes.
# --------------------------------------------------------------------------

def _model_setup(arch: str):
    import jax

    from repro.configs.base import RAPID, get_config
    from repro.models.layers import ParallelCtx
    from repro.models.model import Model

    cfg = get_config(arch).reduced().with_(approx=RAPID)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return m, cfg, params, ParallelCtx()


def _batch_for(cfg, B: int = 2, S: int = 8) -> dict:
    import jax

    rng = jax.random.PRNGKey(0)
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S], "targets": toks[:, 1:]}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.numpy.zeros((B, cfg.frontend_seq, 1024))
    if cfg.family == "vlm":
        batch["patches"] = jax.numpy.zeros((B, cfg.frontend_seq, 1024))
    return batch


def _entry_model_forward():
    m, cfg, params, ctx = _model_setup("yi_6b")
    batch = _batch_for(cfg)
    return (lambda p, b: m.forward(p, b, ctx)), (params, batch), cfg


def _entry_model_forward_moe():
    m, cfg, params, ctx = _model_setup("qwen3_moe_235b_a22b")
    batch = _batch_for(cfg)
    return (lambda p, b: m.forward(p, b, ctx)), (params, batch), cfg


def _entry_model_decode():
    import jax.numpy as jnp

    m, cfg, params, ctx = _model_setup("yi_6b")
    cache = m.init_cache(2, 16)
    tokens = jnp.zeros((2,), jnp.int32)
    return (lambda p, t, c: m.decode_step(p, t, c, ctx)), \
        (params, tokens, cache), cfg


def _entry_model_decode_paged():
    import jax.numpy as jnp

    m, cfg, params, ctx = _model_setup("yi_6b")
    cache = m.init_paged_cache(n_pages=4, page_size=8)
    tokens = jnp.zeros((2, 4), jnp.int32)
    page_table = jnp.zeros((2, 2), jnp.int32)
    offsets = jnp.zeros((2,), jnp.int32)
    n_valid = jnp.full((2,), 4, jnp.int32)
    fn = lambda p, t, c, pt, off, nv: m.decode_paged(  # noqa: E731
        p, t, c, pt, off, nv, ctx)
    return fn, (params, tokens, cache, page_table, offsets, n_valid), cfg


def _entry_trainstep():
    import jax.numpy as jnp

    from repro.train.optimizer import OptConfig
    from repro.train.trainstep import make_train_step

    m, cfg, params, ctx = _model_setup("yi_6b")
    init_opt, step = make_train_step(m, OptConfig(lr=1e-3), ctx)
    opt = init_opt(params)
    batch = _batch_for(cfg)
    return (lambda p, o, b: step(p, o, b, jnp.int32(0))), \
        (params, opt, batch), cfg


def _entry_app_jpeg():
    import jax.numpy as jnp

    from repro.apps.arith import VARIANTS
    from repro.apps.jpeg import QTABLE, roundtrip_blocks

    v = VARIANTS["rapid"]
    blocks = jnp.zeros((16, 8, 8), jnp.float32)
    q = jnp.asarray(QTABLE)
    return (lambda b, qt: roundtrip_blocks(b, v, qt)), (blocks, q), v


def _entry_app_harris():
    import jax.numpy as jnp

    from repro.apps.arith import VARIANTS
    from repro.apps.harris import harris_response

    v = VARIANTS["rapid"]
    g = jnp.zeros((32, 32), jnp.float32)
    return (lambda gx, gy: harris_response(gx, gy, v)), (g, g), v


def _entry_app_pan_tompkins():
    import jax.numpy as jnp

    from repro.apps.arith import VARIANTS
    from repro.apps.pan_tompkins import integrate_energy

    v = VARIANTS["rapid"]
    der = jnp.zeros((256,), jnp.float32)
    return (lambda d: integrate_energy(d, v)), (der,), v


ENTRIES: Dict[str, Callable] = {
    "model_forward": _entry_model_forward,
    "model_forward_moe": _entry_model_forward_moe,
    "model_decode": _entry_model_decode,
    "model_decode_paged": _entry_model_decode_paged,
    "trainstep": _entry_trainstep,
    "app_jpeg": _entry_app_jpeg,
    "app_harris": _entry_app_harris,
    "app_pan_tompkins": _entry_app_pan_tompkins,
}


def run_audit(names: Optional[List[str]] = None
              ) -> Tuple[List[Finding], dict]:
    """Trace every registered entry; returns (findings, per-entry meta)."""
    findings: List[Finding] = []
    meta: dict = {}
    for name in (names or list(ENTRIES)):
        fn, args, static_cfg = ENTRIES[name]()
        got, m = audit_fn(fn, args, name, static_config=static_cfg)
        findings += got
        meta[name] = m
    return findings, meta


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def print_meta(meta: dict, stream=sys.stdout) -> None:
    for name, m in meta.items():
        stream.write(
            f"{name}: {m['eqns_audited']} dot/div eqns, "
            f"{m['escapes']} escaped\n")
        for w in m.get("dup_consts", []):
            stream.write(f"  warning: {w}\n")
        for w in m.get("retrace_hazards", []):
            stream.write(f"  warning: retrace hazard: {w}\n")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.jaxpr_audit",
        description="trace entry points; census dot/div registry escapes")
    ap.add_argument("--entries", default="",
                    help=f"comma-separated subset of {sorted(ENTRIES)}")
    ap.add_argument("--json", default="", metavar="PATH")
    ap.add_argument("--baseline", default="", metavar="PATH")
    args = ap.parse_args(argv)

    names = [n for n in args.entries.split(",") if n] or None
    findings, meta = run_audit(names)
    print_meta(meta)
    result: Optional[CompareResult] = None
    if args.baseline:
        baseline = [f for f in F.load_baseline(args.baseline)
                    if f.layer == "jaxpr"]
        result = F.compare(findings, baseline)
        for f in result.new:
            print(f"NEW escape: {f.where()}: {f.msg}")
        for w in result.warnings:
            print(f"warning: {w}")
        print(f"jaxpr ratchet: {result.summary()}")
        ok = result.ok
    else:
        for f in findings:
            print(f"{f.where()}: {f.msg}")
        ok = not findings

    if args.json:
        F.dump_report(args.json, [], findings, jaxpr_meta=meta,
                      result=result)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
