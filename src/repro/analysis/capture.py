"""Pallas-call capture shim: record kernel geometry with no TPU.

:func:`capture_pallas_calls` monkeypatches
``jax.experimental.pallas.pallas_call`` with a fake that never builds a
kernel: it records the call's grid, BlockSpecs (block shape + index
map), operand/result shapes and dtypes, and the Mosaic
``dimension_semantics``, then returns zeros of the declared out shapes.
The kernel-family wrappers (``log_matmul``, ``fused_*_div``,
``rapid_mul``/``rapid_div``) run unmodified on any host and the
geometry auditor (``repro.analysis.kernel_audit``) checks the captured
calls statically.

Two sharp edges the shim handles:

* **jit-cache pollution.**  The public wrappers are ``jax.jit``-ed; if
  a fake traced under them entered the jit cache, later *real* calls at
  the same shapes would replay the fake and return zeros.  The context
  manager therefore runs everything under ``jax.disable_jit()`` — the
  wrappers execute eagerly and the cache is never consulted or filled.
* **interpret mode drops geometry.**  The wrappers pass
  ``compiler_params=None`` when interpreting on CPU; audit drivers must
  call them with ``interpret=False`` (the fake never compiles anything,
  so this is safe off-TPU) to capture the real ``dimension_semantics``.
"""
from __future__ import annotations

import contextlib
import functools
import inspect
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

__all__ = ["SpecInfo", "CapturedCall", "capture_pallas_calls"]


@dataclass
class SpecInfo:
    """One operand/result of a captured ``pallas_call``."""

    name: str                       # in0/in1/... or out0/out1/...
    shape: Tuple[int, ...]          # full (padded) array shape
    dtype: str
    itemsize: int
    block_shape: Optional[Tuple[int, ...]]  # None: whole-array default
    index_map: Optional[Callable]           # None: whole-array default
    #: BlockSpec memory space ("any" = HBM-resident, kernel DMAs slices
    #: manually); None = the default grid-staged VMEM placement
    memory_space: Optional[str] = None

    def block(self) -> Tuple[int, ...]:
        """Block shape with the whole-array default made explicit."""
        if self.block_shape is None:
            return tuple(self.shape)
        # a None entry in a block shape means "whole dim" in pallas
        return tuple(
            int(s if b is None else b)
            for b, s in zip(self.block_shape, self.shape)
        )

    def map_index(self, *grid_idx: int) -> Tuple[int, ...]:
        """Evaluate the index map at a grid point (python ints in/out)."""
        if self.index_map is None:
            return tuple(0 for _ in self.shape)
        out = self.index_map(*grid_idx)
        if not isinstance(out, tuple):
            out = (out,)
        return tuple(int(v) for v in out)


@dataclass
class CapturedCall:
    """Geometry of one ``pallas_call`` as issued by a kernel wrapper."""

    kernel: Callable                     # as passed (possibly a partial)
    kernel_name: str
    kernel_file: str
    kernel_kwargs: dict                  # merged functools.partial keywords
    grid: Tuple[int, ...]
    in_specs: List[SpecInfo] = field(default_factory=list)
    out_specs: List[SpecInfo] = field(default_factory=list)
    dimension_semantics: Optional[Tuple[str, ...]] = None
    input_output_aliases: Any = None
    interpret: bool = False
    out_is_list: bool = False
    #: manual-pipeline scratch ({"shape", "dtype"} per entry; DMA
    #: semaphores show up with dtype "dma_sem" and no byte cost)
    scratch_shapes: List[dict] = field(default_factory=list)

    def operands(self) -> List[SpecInfo]:
        return list(self.in_specs) + list(self.out_specs)


def _unwrap_kernel(kernel: Callable) -> Tuple[Callable, dict]:
    kwargs: dict = {}
    fn = kernel
    while isinstance(fn, functools.partial):
        kwargs.update(fn.keywords or {})
        fn = fn.func
    return fn, kwargs


def _spec_fields(spec) -> Tuple[Optional[tuple], Optional[Callable],
                                Optional[str]]:
    if spec is None:
        return None, None, None
    ms = getattr(spec, "memory_space", None)
    return (getattr(spec, "block_shape", None),
            getattr(spec, "index_map", None),
            str(ms) if ms is not None else None)


def _scratch_info(scratch_shapes) -> List[dict]:
    out = []
    for s in _as_list(scratch_shapes):
        shape = getattr(s, "shape", None)
        dtype = getattr(s, "dtype", None)
        out.append({
            "shape": tuple(int(d) for d in shape) if shape else (),
            "dtype": getattr(dtype, "__name__", None) or str(dtype),
        })
    return out


def _dimension_semantics(compiler_params) -> Optional[Tuple[str, ...]]:
    if compiler_params is None:
        return None
    if isinstance(compiler_params, dict):
        mosaic = compiler_params.get("mosaic", compiler_params)
        if isinstance(mosaic, dict):
            sem = mosaic.get("dimension_semantics")
        else:
            sem = getattr(mosaic, "dimension_semantics", None)
    else:
        sem = getattr(compiler_params, "dimension_semantics", None)
    return tuple(sem) if sem is not None else None


def _as_list(x) -> list:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


@contextlib.contextmanager
def capture_pallas_calls():
    """Context manager yielding a list filled with :class:`CapturedCall`.

    Inside the block every ``pl.pallas_call`` records its geometry and
    returns zeros; jit is disabled so nothing fake is cached.  Use::

        with capture_pallas_calls() as calls:
            log_matmul(x, w, "rapid10", interpret=False)
        grid = calls[0].grid
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    captured: List[CapturedCall] = []
    real_pallas_call = pl.pallas_call

    def shim(kernel, *, grid=None, in_specs=None, out_specs=None,
             out_shape=None, compiler_params=None, interpret=False,
             input_output_aliases=None, scratch_shapes=None, **_ignored):
        fn, kkwargs = _unwrap_kernel(kernel)
        try:
            kernel_file = inspect.getsourcefile(fn) or "<unknown>"
        except TypeError:  # builtins / C callables
            kernel_file = "<unknown>"
        out_is_list = isinstance(out_shape, (list, tuple))
        out_shapes = _as_list(out_shape)
        out_spec_list = _as_list(out_specs)

        def runner(*operands):
            in_spec_list = _as_list(in_specs)
            if len(in_spec_list) < len(operands):
                in_spec_list += [None] * (len(operands) - len(in_spec_list))
            call = CapturedCall(
                kernel=kernel,
                kernel_name=getattr(fn, "__qualname__", repr(fn)),
                kernel_file=kernel_file,
                kernel_kwargs=kkwargs,
                grid=tuple(int(g) for g in (grid or ())),
                dimension_semantics=_dimension_semantics(compiler_params),
                input_output_aliases=input_output_aliases,
                interpret=bool(interpret),
                out_is_list=out_is_list,
                scratch_shapes=_scratch_info(scratch_shapes),
            )
            for i, (op, spec) in enumerate(zip(operands, in_spec_list)):
                bs, imap, ms = _spec_fields(spec)
                call.in_specs.append(SpecInfo(
                    name=f"in{i}", shape=tuple(op.shape), dtype=str(op.dtype),
                    itemsize=int(op.dtype.itemsize),
                    block_shape=tuple(bs) if bs is not None else None,
                    index_map=imap, memory_space=ms,
                ))
            specs = list(out_spec_list) + [None] * (
                len(out_shapes) - len(out_spec_list))
            for i, (sd, spec) in enumerate(zip(out_shapes, specs)):
                bs, imap, ms = _spec_fields(spec)
                call.out_specs.append(SpecInfo(
                    name=f"out{i}", shape=tuple(sd.shape), dtype=str(sd.dtype),
                    itemsize=int(jnp.dtype(sd.dtype).itemsize),
                    block_shape=tuple(bs) if bs is not None else None,
                    index_map=imap, memory_space=ms,
                ))
            captured.append(call)
            zeros = [jnp.zeros(sd.shape, sd.dtype) for sd in out_shapes]
            return tuple(zeros) if out_is_list else zeros[0]

        return runner

    with jax.disable_jit():
        pl.pallas_call = shim
        try:
            yield captured
        finally:
            pl.pallas_call = real_pallas_call
