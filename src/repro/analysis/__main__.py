"""Combined three-layer audit runner (what the CI ``audit`` job executes).

    PYTHONPATH=src python -m repro.analysis \
        --baseline AUDIT_baseline.json --json AUDIT_PR.json \
        --pipeline-report PIPELINE_REPORT.json --fail-stale

Runs the AST lint, the jaxpr entry-point audit, and the kernel geometry
audit; merges all three into one JSON report; and ratchets against the
committed baseline: allowlisted findings pass, new escapes exit 1 (with
file:line for AST findings, entry/primitive for jaxpr escapes, and
variant/operand for kernel-geometry findings), stale allowlist entries
warn — or fail with ``--fail-stale`` (CI), or are removed mechanically
with ``--prune-stale``.

Regenerating the allowlist after an intentional change is the same
command with the report written *as* the baseline:

    PYTHONPATH=src python -m repro.analysis --json AUDIT_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence

from repro.analysis import findings as F
from repro.analysis import jaxpr_audit, lint

ALL_LAYERS = ("ast", "jaxpr", "kernel")


def run_combined(entries: Optional[List[str]] = None,
                 baseline: Optional[str] = None,
                 json_path: Optional[str] = None,
                 *,
                 layers: Sequence[str] = ALL_LAYERS,
                 fail_stale: bool = False,
                 prune_stale: bool = False,
                 pipeline_report: Optional[str] = None):
    """All layers + ratchet + report; returns (rc, findings, jaxpr_meta).

    The programmatic face of ``python -m repro.analysis``, also driven
    by the operator CLI in :mod:`repro.launch.audit`.
    """
    ast_findings = lint.run_lint() if "ast" in layers else []
    jaxpr_findings: List[F.Finding] = []
    meta: dict = {}
    if "jaxpr" in layers:
        jaxpr_findings, meta = jaxpr_audit.run_audit(entries)
    kernel_findings: List[F.Finding] = []
    kernel_reports: List[dict] = []
    if "kernel" in layers:
        from repro.analysis import kernel_audit
        kernel_findings, kernel_reports = kernel_audit.run_kernel_audit()
    current = ast_findings + jaxpr_findings + kernel_findings

    print(f"ast lint: {len(ast_findings)} finding(s); "
          f"jaxpr audit: {sum(f.count for f in jaxpr_findings)} escaped "
          f"eqn(s) across {len(meta)} entries; "
          f"kernel audit: {len(kernel_findings)} finding(s) across "
          f"{len(kernel_reports)} variants")
    jaxpr_audit.print_meta(meta)

    if pipeline_report and kernel_reports:
        from repro.analysis import kernel_audit
        with open(pipeline_report, "w") as fh:
            json.dump(kernel_audit.pipeline_report_doc(kernel_reports),
                      fh, indent=2)
            fh.write("\n")
        unsafe = [r["variant"] for r in kernel_reports
                  if not r["double_buffer_safe"]]
        print(f"pipeline-legality report ({len(kernel_reports)} kernels, "
              f"{len(unsafe)} not double-buffer-safe) written to "
              f"{pipeline_report}")

    result = None
    if baseline:
        # hard-error rules (rules.HARD_ERROR_RULES, e.g. RPD009) are
        # non-baselineable: committed allowlist entries for them are
        # dropped before the ratchet so any occurrence is always new
        from repro.analysis import rules
        allowed = [f for f in F.load_baseline(baseline)
                   if f.rule not in rules.HARD_ERROR_RULES]
        result = F.compare(current, allowed)
        for f in result.new:
            print(f"NEW: {f.where()}: [{f.rule}] {f.msg}")
            if f.code:
                print(f"    {f.code}")
        for w in result.warnings:
            print(f"warning: {w}")
        print(f"ratchet vs {baseline}: {result.summary()}")
        ok = result.ok
        if prune_stale and result.stale:
            removed = F.prune_stale(baseline, current)
            print(f"pruned {removed} stale entr"
                  f"{'y' if removed == 1 else 'ies'} from {baseline}")
        elif fail_stale and result.stale:
            ok = False
            print(f"FAIL: {len(result.stale)} stale baseline entr"
                  f"{'y' if len(result.stale) == 1 else 'ies'} "
                  "(--fail-stale; shrink the allowlist with --prune-stale)",
                  file=sys.stderr)
    else:
        lint.print_findings(current)
        ok = not current

    if json_path:
        F.dump_report(json_path, ast_findings, jaxpr_findings,
                      kernel_findings, jaxpr_meta=meta, result=result)
        print(f"report written to {json_path}")

    if result is not None and not result.ok:
        print("FAIL: new registry escapes (route through qmatmul/qdiv/"
              "qsoftmax_div/qrms_div, mark '# audit: exact — reason', fix "
              "the kernel geometry, or regenerate the baseline if "
              "intentional)", file=sys.stderr)
    elif not ok and not baseline:
        print("FAIL: findings with no baseline to ratchet against",
              file=sys.stderr)
    return (0 if ok else 1), current, meta


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="RAPID dispatch-coverage audit (AST lint + jaxpr "
                    "entry-point census + kernel geometry)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the merged three-layer JSON report")
    ap.add_argument("--baseline", default="", metavar="PATH",
                    help="ratchet against this committed baseline")
    ap.add_argument("--entries", default="",
                    help="comma-separated jaxpr entry subset (default all)")
    ap.add_argument("--layers", default=",".join(ALL_LAYERS),
                    help="comma-separated layer subset "
                         f"(default {','.join(ALL_LAYERS)})")
    ap.add_argument("--pipeline-report", default="", metavar="PATH",
                    help="write the kernel pipeline-legality report JSON")
    ap.add_argument("--fail-stale", action="store_true",
                    help="exit nonzero on stale baseline entries instead of "
                         "warning (CI mode)")
    ap.add_argument("--prune-stale", action="store_true",
                    help="rewrite the baseline without stale entries")
    args = ap.parse_args(argv)
    layers = tuple(x for x in args.layers.split(",") if x)
    bad = [x for x in layers if x not in ALL_LAYERS]
    if bad:
        ap.error(f"unknown layer(s) {bad}; pick from {ALL_LAYERS}")
    if args.prune_stale and not args.baseline:
        ap.error("--prune-stale needs --baseline")
    rc, _, _ = run_combined(
        entries=[n for n in args.entries.split(",") if n] or None,
        baseline=args.baseline or None, json_path=args.json or None,
        layers=layers, fail_stale=args.fail_stale,
        prune_stale=args.prune_stale,
        pipeline_report=args.pipeline_report or None)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
