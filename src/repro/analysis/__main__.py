"""Combined two-layer audit runner (what the CI ``audit`` job executes).

    PYTHONPATH=src python -m repro.analysis \
        --baseline AUDIT_baseline.json --json AUDIT_PR.json

Runs the AST lint and the jaxpr entry-point audit, merges both into one
JSON report, and ratchets against the committed baseline: allowlisted
findings pass, new escapes exit 1 (with file:line for AST findings and
entry/primitive for jaxpr escapes), stale allowlist entries warn.

Regenerating the allowlist after an intentional change is the same
command with the report written *as* the baseline:

    PYTHONPATH=src python -m repro.analysis --json AUDIT_baseline.json
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis import findings as F
from repro.analysis import jaxpr_audit, lint


def run_combined(entries: Optional[List[str]] = None,
                 baseline: Optional[str] = None,
                 json_path: Optional[str] = None):
    """Both layers + ratchet + report; returns (rc, findings, jaxpr_meta).

    The programmatic face of ``python -m repro.analysis``, also driven
    by the operator CLI in :mod:`repro.launch.audit`.
    """
    ast_findings = lint.run_lint()
    jaxpr_findings, meta = jaxpr_audit.run_audit(entries)
    current = ast_findings + jaxpr_findings

    print(f"ast lint: {len(ast_findings)} finding(s); "
          f"jaxpr audit: {sum(f.count for f in jaxpr_findings)} escaped "
          f"eqn(s) across {len(meta)} entries")
    jaxpr_audit.print_meta(meta)

    result = None
    if baseline:
        result = F.compare(current, F.load_baseline(baseline))
        for f in result.new:
            print(f"NEW: {f.where()}: [{f.rule}] {f.msg}")
            if f.code:
                print(f"    {f.code}")
        for w in result.warnings:
            print(f"warning: {w}")
        print(f"ratchet vs {baseline}: {result.summary()}")
        ok = result.ok
    else:
        lint.print_findings(current)
        ok = not current

    if json_path:
        F.dump_report(json_path, ast_findings, jaxpr_findings,
                      jaxpr_meta=meta, result=result)
        print(f"report written to {json_path}")

    if not ok:
        print("FAIL: new registry escapes (route through qmatmul/qdiv/"
              "qsoftmax_div/qrms_div, mark '# audit: exact — reason', or "
              "regenerate the baseline if intentional)", file=sys.stderr)
    return (0 if ok else 1), current, meta


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="RAPID dispatch-coverage audit (AST lint + jaxpr "
                    "entry-point census)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the merged two-layer JSON report")
    ap.add_argument("--baseline", default="", metavar="PATH",
                    help="ratchet against this committed baseline")
    ap.add_argument("--entries", default="",
                    help="comma-separated jaxpr entry subset (default all)")
    args = ap.parse_args(argv)
    rc, _, _ = run_combined(
        entries=[n for n in args.entries.split(",") if n] or None,
        baseline=args.baseline or None, json_path=args.json or None)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
