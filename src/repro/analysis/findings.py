"""Finding schema + baseline ratchet shared by both audit layers.

A :class:`Finding` is one escape — an operation that bypasses the
backend registry — discovered either by the AST lint (layer 1,
``repro.analysis.rules``) or by the jaxpr audit (layer 2,
``repro.analysis.jaxpr_audit``).  Both layers feed one JSON report and
one committed baseline (``AUDIT_baseline.json`` at the repo root).

The baseline is a **ratchet**, not a snapshot:

  * a current finding whose key is in the baseline is *allowlisted* —
    a known escape awaiting burn-down (the apps ROADMAP item);
  * a current finding whose key is NOT in the baseline is *new* and
    fails CI;
  * a baseline key with no current finding is *stale* — it warns (so
    the allowlist is shrunk in the same PR that fixes the escape) but
    does not fail.

Keys deliberately exclude line numbers so unrelated edits that shift
code do not churn the baseline:

  * AST findings key on ``(rule, file, code)`` where ``code`` is the
    stripped source line — a *moved* escape still matches, a *second
    copy* of the same line is a new escape (multiset semantics);
  * jaxpr findings key on ``(entry, primitive, file)`` — trace-level
    line attribution is too version-dependent (jax 0.4.x vs 0.8 lower
    differently) to ratchet on, but a dot_general/div escaping in a
    file that had none is always a failure.  Count *increases* within
    an existing key are reported as warnings.
  * kernel findings (layer 3, ``repro.analysis.kernel_audit``) key on
    ``(rule, entry, primitive)`` where ``entry`` is the audited kernel
    variant id (``family/shape_class``) and ``primitive`` the operand
    label (``in0``/``out0``/``kernel``) — geometry is derived from
    BlockSpecs, identical on every jax pin, so the key carries no
    file/line at all.
"""
from __future__ import annotations

import json
from collections import Counter
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = [
    "Finding",
    "CompareResult",
    "compare",
    "load_baseline",
    "dump_report",
    "prune_stale",
    "findings_from_dicts",
]

#: files the jaxpr layer could not attribute to a source line (older /
#: newer jax dropping source info on some transformed eqns).  These are
#: reported but never fail the ratchet — failing on them would make the
#: gate flap across jax pins.
UNATTRIBUTED = "<unattributed>"


@dataclass(frozen=True)
class Finding:
    """One registry-bypassing operation (either audit layer)."""

    layer: str            # "ast" | "jaxpr" | "kernel"
    rule: str             # RPD001..004 (ast) | "escape" (jaxpr) | RPD005..008
    file: str             # repo-relative path (or UNATTRIBUTED)
    line: int             # 1-based; informative only, not part of the key
    msg: str              # human-readable description
    code: str = ""        # stripped source line (ast layer)
    entry: str = ""       # entry point (jaxpr) | kernel variant id (kernel)
    primitive: str = ""   # jax primitive (jaxpr) | operand label (kernel)
    count: int = 1        # occurrences under this key (jaxpr layer)

    def key(self) -> Tuple[str, ...]:
        if self.layer == "ast":
            return ("ast", self.rule, self.file, self.code)
        if self.layer == "kernel":
            return ("kernel", self.rule, self.entry, self.primitive)
        return ("jaxpr", self.entry, self.primitive, self.file)

    def where(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        if self.layer == "jaxpr":
            return f"{self.entry}: {self.primitive} @ {loc}"
        if self.layer == "kernel":
            return f"{self.entry}: {self.primitive} ({self.file})"
        return loc


def findings_from_dicts(items: List[dict]) -> List[Finding]:
    fields = {f for f in Finding.__dataclass_fields__}
    return [Finding(**{k: v for k, v in d.items() if k in fields})
            for d in items]


@dataclass
class CompareResult:
    """Ratchet verdict: new findings fail, stale entries warn."""

    new: List[Finding] = field(default_factory=list)
    matched: List[Finding] = field(default_factory=list)
    stale: List[Finding] = field(default_factory=list)
    warnings: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.new

    def summary(self) -> str:
        parts = [f"{len(self.matched)} allowlisted",
                 f"{len(self.new)} new", f"{len(self.stale)} stale"]
        if self.warnings:
            parts.append(f"{len(self.warnings)} warnings")
        return ", ".join(parts)


def compare(current: List[Finding], baseline: List[Finding]) -> CompareResult:
    """Multiset ratchet: every current key must be covered by the baseline.

    AST keys may legitimately repeat (two identical escape lines in one
    file), so coverage is counted per key.  jaxpr findings arrive
    pre-aggregated (one Finding per key with a ``count``); a count
    increase within a covered key warns instead of failing — see the
    module docstring for why.
    """
    res = CompareResult()
    base_keys = Counter(f.key() for f in baseline)
    base_by_key: Dict[Tuple[str, ...], Finding] = {
        f.key(): f for f in baseline}
    seen = Counter()
    for f in sorted(current, key=lambda f: (f.file, f.line, f.rule)):
        k = f.key()
        seen[k] += 1
        if f.layer == "jaxpr" and f.file == UNATTRIBUTED:
            res.matched.append(f)
            res.warnings.append(
                f"unattributed jaxpr escape (not ratcheted): {f.where()}")
            continue
        if seen[k] <= base_keys[k]:
            res.matched.append(f)
            b = base_by_key[k]
            if f.layer == "jaxpr" and f.count > b.count:
                res.warnings.append(
                    f"escape count grew {b.count} -> {f.count} for "
                    f"{f.where()} (allowlisted file; not failing)")
        else:
            res.new.append(f)
    for f in baseline:
        k = f.key()
        if seen[k] < base_keys[k]:
            # consume one stale slot per unmatched baseline entry
            seen[k] += 1
            res.stale.append(f)
            res.warnings.append(
                f"stale baseline entry (escape fixed? shrink the "
                f"allowlist): {f.where()}")
    return res


#: baseline/report arrays, one per audit layer
LAYER_SECTIONS = ("ast", "jaxpr", "kernel")


def load_baseline(path: str) -> List[Finding]:
    with open(path) as fh:
        data = json.load(fh)
    items: List[dict] = []
    for section in LAYER_SECTIONS:
        items += data.get(section, [])
    return findings_from_dicts(items)


def dump_report(path: str, ast_findings: List[Finding],
                jaxpr_findings: List[Finding],
                kernel_findings: Optional[List[Finding]] = None,
                jaxpr_meta: Optional[dict] = None,
                result: Optional[CompareResult] = None) -> dict:
    """Write the merged three-layer JSON report (also the baseline format).

    A report file doubles as a baseline: ``load_baseline`` reads the
    same ``ast`` / ``jaxpr`` / ``kernel`` arrays, so regenerating the
    allowlist is ``python -m repro.analysis --json AUDIT_baseline.json``.
    """
    doc: dict = {
        "version": 1,
        "ast": [asdict(f) for f in ast_findings],
        "jaxpr": [asdict(f) for f in jaxpr_findings],
        "kernel": [asdict(f) for f in (kernel_findings or [])],
    }
    if jaxpr_meta is not None:
        doc["jaxpr_meta"] = jaxpr_meta
    if result is not None:
        doc["ratchet"] = {
            "ok": result.ok,
            "new": [asdict(f) for f in result.new],
            "stale": [asdict(f) for f in result.stale],
            "warnings": result.warnings,
        }
    if path:
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=False)
            fh.write("\n")
    return doc


def prune_stale(path: str, current: List[Finding]) -> int:
    """Drop baseline entries with no matching current finding, in place.

    The mechanical arm of the ratchet's stale warning: after a PR fixes
    an allowlisted escape, ``python -m repro.analysis --baseline
    AUDIT_baseline.json --prune-stale`` rewrites the baseline without
    the fixed entries (multiset semantics — with two identical
    allowlisted lines and one fixed, one entry survives).  Sections
    other than the per-layer finding arrays (``jaxpr_meta`` etc.) are
    preserved.  Returns the number of entries removed.
    """
    with open(path) as fh:
        data = json.load(fh)
    baseline = load_baseline(path)
    stale = Counter(f.key() for f in compare(current, baseline).stale)
    removed = 0
    for section in LAYER_SECTIONS:
        kept: List[dict] = []
        for item in data.get(section, []):
            (f,) = findings_from_dicts([item])
            k = f.key()
            if stale[k] > 0:
                stale[k] -= 1
                removed += 1
            else:
                kept.append(item)
        if section in data:
            data[section] = kept
    if removed:
        with open(path, "w") as fh:
            json.dump(data, fh, indent=2, sort_keys=False)
            fh.write("\n")
    return removed
