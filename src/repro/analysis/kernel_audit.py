"""Layer-3 audit: static geometry checks for every Pallas kernel family.

The dispatch auditor (layers 1+2) proves mul/div *route through* the
registry; this layer proves the kernels the registry dispatches are
*geometrically legal* before they ever touch a TPU.  Every kernel
family registered in ``core/backend.py`` — ``log_matmul`` (matmul),
``fused_div`` (softmax/rms/eltwise/row-broadcast divides), plus the
integer ``rapid_mul``/``rapid_div`` units — is driven through its
public wrapper under the capture shim (:mod:`repro.analysis.capture`),
and each captured ``pallas_call``'s grid/BlockSpec/index-map geometry
is checked per shape class:

  RPD005  VMEM working set: per-grid-step tile bytes (grid-varying
          operands counted ``PIPELINE_BUFFERS`` times, grid-invariant
          LUT constants once) against the explicit per-platform budget
          in :mod:`repro.kernels.budget` — the same constants the
          ``kernels/spec.py::resolve_spec`` heuristics derive block
          sizes from and the autotuner's legality filter enforces.
  RPD006  tiling legality: block lane dim %128 (or == the array dim),
          sublane dim %8, and blocks dividing the padded array dims so
          no implicit tail padding sneaks in.
  RPD007  tail coverage: index maps are surjective onto the padded
          array's block grid and never map out of range — a
          non-surjective map silently drops elements (the class of bug
          the PR-4 K-tail fix patched by hand).
  RPD008  write-aliasing races: an output tile revisited across a grid
          dimension (the ``kk`` accumulation in ``log_matmul``) must be
          written only by accumulation (``+=``) or under first/last-
          visit ``pl.when`` guards, and the revisited dim must not be
          declared "parallel".

Two kernel formulations pass through here.  Grid-staged kernels let
Mosaic stage VMEM tiles per grid step; all four checks apply per
operand.  Manual-pipeline kernels (the depth>=2 paths of
``log_matmul``/``fused_div`` and the flash-decode kernel) declare bulk
operands in ANY memory and DMA slices through depth-deep VMEM scratch
themselves — for those operands RPD006/RPD007 don't apply (coverage is
the in-kernel copy loop's job, proven bit-exact by the parity sweep)
and RPD005 prices the declared scratch instead.

Alongside findings, the audit emits a **pipeline-legality report** per
variant — grid, semantics, pipeline depth, working set (incl. scratch),
revisit structure, and whether double-buffering is safe — the contract
future kernel changes must preserve (``PIPELINE_REPORT.json`` at the
repo root).  Findings flow through the ``findings.compare`` ratchet
into the ``kernel`` section of ``AUDIT_baseline.json``.
"""
from __future__ import annotations

import ast
import functools
import inspect
import itertools
import textwrap
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.capture import CapturedCall, SpecInfo, capture_pallas_calls
from repro.analysis.findings import Finding
from repro.analysis.rules import KERNEL_RULES  # noqa: F401  (re-export)
from repro.kernels import budget

__all__ = [
    "KERNEL_RULES",
    "KernelWrite",
    "analyze_kernel_writes",
    "audit_call",
    "iter_variants",
    "run_kernel_audit",
    "registry_coverage",
]


# --------------------------------------------------------------------------
# kernel-body write analysis (guards + accumulation discipline)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class KernelGuard:
    """One ``@pl.when(pl.program_id(dim) == value)`` context."""

    dim: Optional[int]      # grid dim compared, None if unrecognised
    value: Optional[int]    # comparison value, None if not evaluable


@dataclass(frozen=True)
class KernelWrite:
    """One subscript store to a ``*_ref`` name inside a kernel body."""

    target: str             # e.g. "o_ref"
    kind: str               # "assign" (=) | "accum" (+=)
    guards: Tuple[KernelGuard, ...]

    def guarded_visit(self, dim: int, first: int, last: int) -> bool:
        """Write only happens on the first or last visit along ``dim``."""
        return any(g.dim == dim and g.value in (first, last)
                   for g in self.guards)


def _guard_from_decorator(dec: ast.expr, env: dict) -> Optional[KernelGuard]:
    """Parse ``pl.when(pl.program_id(d) == expr)`` -> KernelGuard."""
    if not (isinstance(dec, ast.Call) and isinstance(dec.func, ast.Attribute)
            and dec.func.attr == "when" and dec.args):
        return None
    pred = dec.args[0]
    if not (isinstance(pred, ast.Compare) and len(pred.ops) == 1
            and isinstance(pred.ops[0], ast.Eq)):
        return KernelGuard(dim=None, value=None)
    sides = [pred.left, pred.comparators[0]]

    def _program_id_dim(node) -> Optional[int]:
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and node.func.attr == "program_id" and node.args
                and isinstance(node.args[0], ast.Constant)):
            return int(node.args[0].value)
        return None

    for a, b in (sides, sides[::-1]):
        dim = _program_id_dim(a)
        if dim is None:
            continue
        try:
            value = eval(  # noqa: S307 - audited repo source, static ints
                compile(ast.Expression(b), "<guard>", "eval"),
                {"__builtins__": {}}, dict(env))
            return KernelGuard(dim=dim, value=int(value))
        except Exception:
            return KernelGuard(dim=dim, value=None)
    return KernelGuard(dim=None, value=None)


def analyze_kernel_writes(kernel: Callable) -> Optional[List[KernelWrite]]:
    """Classify every ``*_ref[...]`` store in a kernel body.

    ``kernel`` may be a ``functools.partial``; its keywords become the
    evaluation environment for guard predicates (so ``pl.program_id(2)
    == nk - 1`` resolves to a concrete visit index).  Returns ``None``
    when the source is unavailable — callers must treat that as
    *unproven*, not clean.
    """
    fn, env = kernel, {}
    while isinstance(fn, functools.partial):
        env.update(fn.keywords or {})
        fn = fn.func
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError):
        return None
    writes: List[KernelWrite] = []

    def ref_name(target) -> Optional[str]:
        if (isinstance(target, ast.Subscript)
                and isinstance(target.value, ast.Name)
                and target.value.id.endswith("_ref")):
            return target.value.id
        return None

    def walk(body, guards: Tuple[KernelGuard, ...]):
        for node in body:
            if isinstance(node, ast.FunctionDef):
                extra = [g for g in
                         (_guard_from_decorator(d, env)
                          for d in node.decorator_list) if g is not None]
                walk(node.body, guards + tuple(extra))
                continue
            if isinstance(node, ast.Assign):
                targets = []
                for t in node.targets:
                    targets += t.elts if isinstance(t, ast.Tuple) else [t]
                for t in targets:
                    name = ref_name(t)
                    if name:
                        writes.append(KernelWrite(name, "assign", guards))
            elif isinstance(node, ast.AugAssign):
                name = ref_name(node.target)
                if name:
                    kind = "accum" if isinstance(node.op, ast.Add) else "assign"
                    writes.append(KernelWrite(name, kind, guards))
            for child_body in (getattr(node, "body", None),
                               getattr(node, "orelse", None),
                               getattr(node, "finalbody", None)):
                if isinstance(child_body, list) and not isinstance(
                        node, ast.FunctionDef):
                    walk(child_body, guards)

    for top in ast.walk(tree):
        if isinstance(top, (ast.FunctionDef, ast.AsyncFunctionDef)):
            walk(top.body, ())
            break
    return writes


# --------------------------------------------------------------------------
# geometry checks over one captured call
# --------------------------------------------------------------------------

def _grid_points(grid: Sequence[int]) -> List[Tuple[int, ...]]:
    return list(itertools.product(*[range(g) for g in grid])) or [()]

def _rel_file(path: str) -> str:
    marker = "src/repro/"
    i = path.replace("\\", "/").find(marker)
    return path[i:] if i >= 0 else path


def _block_grid(spec: SpecInfo) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """(block shape, number of blocks per dim) for one operand."""
    blk = spec.block()
    nblocks = tuple(-(-s // b) for s, b in zip(spec.shape, blk))
    return blk, nblocks


def _is_manual(spec: SpecInfo) -> bool:
    """ANY-memory operand: HBM-resident, DMA'd manually by the kernel."""
    ms = getattr(spec, "memory_space", None)
    return ms is not None and "any" in str(ms).lower()


def _scratch_bytes(entry: dict) -> int:
    """VMEM bytes of one scratch allocation (0 for DMA semaphores)."""
    import numpy as np
    try:
        itemsize = np.dtype(entry.get("dtype")).itemsize
    except TypeError:
        return 0
    return budget.tile_bytes(entry.get("shape", ()), itemsize)


def audit_call(call: CapturedCall, variant: str, family: str,
               platform: str = "tpu") -> Tuple[List[Finding], dict]:
    """All four checks over one captured ``pallas_call`` geometry."""
    findings: List[Finding] = []
    file = _rel_file(call.kernel_file)

    def emit(rule: str, operand: str, msg: str):
        findings.append(Finding(
            layer="kernel", rule=rule, file=file, line=0, msg=msg,
            entry=variant, primitive=operand))

    pts = _grid_points(call.grid)
    visits: Dict[str, Dict[Tuple[int, ...], List[Tuple[int, ...]]]] = {}
    operands = call.operands()
    for spec in operands:
        if _is_manual(spec):
            # ANY-memory operand: HBM-resident, the kernel body DMAs
            # slices into explicit VMEM scratch.  Grid-staging rules
            # (RPD006/RPD007) don't apply — the VMEM cost and the
            # coverage obligation live with the scratch slots and the
            # in-kernel copy loop, which the parity sweep exercises
            # bit-exactly against the grid formulation.
            visits[spec.name] = {}
            continue
        blk, nblocks = _block_grid(spec)

        # RPD006: lane/sublane alignment + block divides the padded dim
        if blk and not (blk[-1] % budget.LANE == 0
                        or blk[-1] == spec.shape[-1]):
            emit("RPD006", spec.name,
                 f"lane dim {blk[-1]} of block {blk} is neither %"
                 f"{budget.LANE} nor the full array dim {spec.shape[-1]}")
        if len(blk) >= 2 and not (blk[-2] % budget.SUBLANE == 0
                                  or blk[-2] == spec.shape[-2]):
            emit("RPD006", spec.name,
                 f"sublane dim {blk[-2]} of block {blk} is neither %"
                 f"{budget.SUBLANE} nor the full array dim {spec.shape[-2]}")
        for d, (s, b) in enumerate(zip(spec.shape, blk)):
            if s % b:
                emit("RPD006", spec.name,
                     f"block dim {b} does not divide padded array dim {s} "
                     f"(axis {d}): implicit tail block")

        # RPD007: index map in range + surjective over the block grid
        seen: Dict[Tuple[int, ...], List[Tuple[int, ...]]] = {}
        out_of_range = 0
        for p in pts:
            bidx = spec.map_index(*p)
            if len(bidx) != len(spec.shape):
                emit("RPD007", spec.name,
                     f"index map arity {len(bidx)} != rank {len(spec.shape)}")
                break
            if any(not 0 <= i < nb for i, nb in zip(bidx, nblocks)):
                out_of_range += 1
                continue
            seen.setdefault(bidx, []).append(p)
        visits[spec.name] = seen
        if out_of_range:
            emit("RPD007", spec.name,
                 f"index map leaves the array for {out_of_range}/{len(pts)} "
                 "grid points")
        total_blocks = 1
        for nb in nblocks:
            total_blocks *= nb
        missing = [b for b in itertools.product(*[range(n) for n in nblocks])
                   if b not in seen]
        if missing:
            emit("RPD007", spec.name,
                 f"{len(missing)} of {total_blocks} blocks never visited "
                 f"(first: {missing[0]}) — elements silently dropped")

    # RPD005: per-grid-step VMEM working set vs the shared budget.
    # Grid-staged operands pay PIPELINE_BUFFERS copies when grid-varying;
    # ANY-memory operands pay nothing here (their VMEM residency is the
    # explicit scratch, already sized depth-deep by the wrapper).
    working_set = 0
    op_report = []
    for spec in operands:
        manual = _is_manual(spec)
        blk, _ = _block_grid(spec)
        varying = len(visits.get(spec.name, {})) > 1
        buffers = 0 if manual else (
            budget.PIPELINE_BUFFERS if varying else 1)
        nbytes = budget.tile_bytes(blk, spec.itemsize) * buffers
        working_set += nbytes
        op_report.append({
            "name": spec.name, "shape": list(spec.shape),
            "block": list(blk), "dtype": spec.dtype,
            "memory_space": spec.memory_space,
            "grid_varying": varying, "vmem_bytes": nbytes,
        })
    scratch_bytes = sum(_scratch_bytes(s) for s in call.scratch_shapes)
    working_set += scratch_bytes
    vmem_budget = budget.vmem_budget(platform)
    if working_set > vmem_budget:
        emit("RPD005", "kernel",
             f"working set {working_set} B (incl. double buffers) exceeds "
             f"the {platform} budget {vmem_budget} B "
             "(repro.kernels.budget.VMEM_BUDGET_BYTES)")

    # RPD008: output revisits must be sequential + write-disciplined
    revisit_dims: Dict[str, List[int]] = {}
    for spec in call.out_specs:
        dims = set()
        for bidx, plist in visits.get(spec.name, {}).items():
            if len(plist) > 1:
                for d in range(len(call.grid)):
                    if len({p[d] for p in plist}) > 1:
                        dims.add(d)
        revisit_dims[spec.name] = sorted(dims)
    any_revisit = any(revisit_dims.values())
    writes = analyze_kernel_writes(call.kernel) if any_revisit else []
    discipline = "single-visit"
    for spec in call.out_specs:
        for d in revisit_dims[spec.name]:
            sem = (call.dimension_semantics[d]
                   if call.dimension_semantics else None)
            if sem == "parallel":
                emit("RPD008", spec.name,
                     f"output revisited across grid dim {d} declared "
                     "'parallel' — concurrent tile writes race")
            if writes is None:
                emit("RPD008", spec.name,
                     "kernel source unavailable: cannot prove revisit write "
                     "discipline")
                discipline = "unproven"
                continue
            bad = [w for w in writes
                   if w.kind == "assign"
                   and not w.guarded_visit(d, 0, call.grid[d] - 1)]
            if bad:
                emit("RPD008", spec.name,
                     f"plain '=' store to {bad[0].target} not guarded to the "
                     f"first/last visit of revisited grid dim {d} "
                     "(use accumulation or pl.when(program_id == 0 / nk-1))")
                discipline = "raced"
            elif discipline == "single-visit":
                discipline = "accumulate+first/last-guard"

    ds = list(call.dimension_semantics) if call.dimension_semantics else None
    depth = int(call.kernel_kwargs.get("depth", 1))
    manual_ops = [s.name for s in operands if _is_manual(s)]
    safe = not findings and call.input_output_aliases in (None, {}, ())
    if safe:
        staged = ("manual async-copy pipeline: HBM operands "
                  f"({', '.join(manual_ops)}) rotate through depth-{depth} "
                  "VMEM scratch, next-slice fetch overlapping compute"
                  if manual_ops else
                  "input tiles are pure functions of the grid index "
                  "(prefetch for step t+1 never depends on step t's "
                  "stores)")
        reason = (staged + "; outputs are "
                  + ("revisited only along sequential dims with "
                     "accumulate/first/last-guarded writes"
                     if any_revisit else "written exactly once")
                  + f"; buffered working set {working_set} B fits the "
                  f"{vmem_budget} B budget")
    else:
        reason = ("; ".join(f"[{f.rule}] {f.msg}" for f in findings)
                  or "input/output aliasing defeats independent prefetch")
    report = {
        "variant": variant,
        "family": family,
        "kernel": call.kernel_name,
        "file": file,
        "grid": list(call.grid),
        "dimension_semantics": ds,
        "pipeline_depth": depth,
        "operands": op_report,
        "working_set_bytes": working_set,
        "scratch_bytes": scratch_bytes,
        "vmem_budget_bytes": vmem_budget,
        "output_revisit_dims": revisit_dims,
        "write_discipline": discipline,
        "double_buffer_safe": safe,
        "reason": reason,
    }
    return findings, report


# --------------------------------------------------------------------------
# variant enumeration: every registered kernel family x bench shape class
# --------------------------------------------------------------------------

#: audited kernel family -> registry family in core/backend.py (the int
#: units have no registry row of their own; they are the faithful-port
#: elementwise path behind the scheme zoo)
REGISTRY_FAMILY = {
    "log_matmul": "matmul",
    "fused_softmax": "softmax_div",
    "fused_rms": "rms_div",
    "fused_div_eltwise": "div",
    "fused_div_rowbcast": "div",
    "flash_attn": "decode_attn",
    "rapid_mul": None,
    "rapid_div": None,
}


def _depth_spec(depth: int):
    from repro.kernels.spec import KernelSpec, PipelineSpec
    return KernelSpec(pipeline=PipelineSpec(depth=depth))


def _drive_log_matmul(m, n, k, **kwargs):
    import jax.numpy as jnp
    from repro.kernels.log_matmul.ops import log_matmul
    x = jnp.zeros((m, k), jnp.float32)
    w = jnp.zeros((k, n), jnp.float32)
    log_matmul(x, w, "rapid10", interpret=False, **kwargs)


def _log_matmul_epilogues():
    from repro.core.backend import Epilogue
    import jax.numpy as jnp
    return {
        "plain": lambda n: {},
        "bias_silu": lambda n: dict(bias=jnp.zeros((n,), jnp.float32),
                                    activation="silu"),
        "rms_keep_prenorm": lambda n: dict(
            epilogue=Epilogue(norm="rms", div_scheme="rapid9",
                              keep_prenorm=True),
            residual=None),
        "softmax": lambda n: dict(
            epilogue=Epilogue(norm="softmax", div_scheme="rapid9")),
    }


def iter_variants() -> List[Tuple[str, str, Callable[[], None]]]:
    """(variant_id, family, driver) for every family x shape class.

    Shape classes mirror the bench sweep plus the degenerate cases the
    block picker historically got wrong: K tails in (128, 512) not a
    multiple of 128, M/N smaller than one tile, realistic model widths
    that trigger the norm-epilogue slab rebalancing.
    """
    import jax.numpy as jnp

    variants: List[Tuple[str, str, Callable[[], None]]] = []

    matmul_shapes = {
        "square512": (512, 512, 512),
        "ktail130": (256, 256, 130),
        "skinny_m4": (4, 512, 512),
        "ntail300": (64, 300, 256),
        # K > MAX_BK: the only class where output tiles are *revisited*
        # across the sequential kk dim — the RPD008 race check is live
        "deepk2048": (64, 256, 2048),
    }
    eps = _log_matmul_epilogues()
    for sname, (m, n, k) in matmul_shapes.items():
        # deepk2048 pins depth=1: K > MAX_BK on the *grid* formulation
        # is the one geometry where output tiles are revisited, keeping
        # the RPD008 race checker exercised on real kernel source
        kw = dict(spec=_depth_spec(1)) if sname == "deepk2048" else {}
        variants.append((
            f"log_matmul/{sname}/plain", "log_matmul",
            functools.partial(_drive_log_matmul, m, n, k, **kw)))
    # explicit pipeline depths either side of the PIPELINE_BUFFERS
    # default (which every variant above audits implicitly)
    for depth in (1, 3):
        m, n, k = matmul_shapes["square512"]
        variants.append((
            f"log_matmul/square512/depth{depth}", "log_matmul",
            functools.partial(_drive_log_matmul, m, n, k,
                              spec=_depth_spec(depth))))
    m, n, k = matmul_shapes["deepk2048"]
    variants.append((
        "log_matmul/deepk2048/depth2", "log_matmul",
        functools.partial(_drive_log_matmul, m, n, k,
                          spec=_depth_spec(2))))
    for ename, mk in eps.items():
        if ename == "plain":
            continue
        m, n, k = matmul_shapes["square512"]
        kw = {k2: v for k2, v in mk(n).items() if v is not None}
        variants.append((
            f"log_matmul/square512/{ename}", "log_matmul",
            functools.partial(_drive_log_matmul, m, n, k, **kw)))
    # realistic MLP width: exercises the norm-epilogue VMEM rebalance
    from repro.core.backend import Epilogue
    variants.append((
        "log_matmul/mlp128x4096/rms", "log_matmul",
        functools.partial(
            _drive_log_matmul, 128, 4096, 512,
            epilogue=Epilogue(norm="rms", div_scheme="rapid9"))))

    def drive_softmax(m, n, spec=None):
        from repro.kernels.fused_div.ops import fused_softmax_div
        fused_softmax_div(jnp.zeros((m, n), jnp.float32), "rapid9",
                          spec=spec, interpret=False)

    def drive_rms(m, n):
        from repro.kernels.fused_div.ops import fused_rms_div
        fused_rms_div(jnp.zeros((m, n), jnp.float32), 1e-6, "rapid9",
                      interpret=False)

    def drive_eltwise(m, n):
        from repro.kernels.fused_div.ops import fused_elementwise_div
        fused_elementwise_div(jnp.zeros((m, n), jnp.float32),
                              jnp.ones((m, n), jnp.float32), "rapid9",
                              interpret=False)

    def drive_rowbcast(m, n):
        from repro.kernels.fused_div.ops import fused_elementwise_div
        fused_elementwise_div(jnp.zeros((m, n), jnp.float32),
                              jnp.ones((m, 1), jnp.float32), "rapid9",
                              interpret=False)

    variants += [
        ("fused_softmax/rows64x1000", "fused_softmax",
         functools.partial(drive_softmax, 64, 1000)),
        ("fused_softmax/rows8x128", "fused_softmax",
         functools.partial(drive_softmax, 8, 128)),
        ("fused_rms/rows32x300", "fused_rms",
         functools.partial(drive_rms, 32, 300)),
        ("fused_div_eltwise/tiled16x256", "fused_div_eltwise",
         functools.partial(drive_eltwise, 16, 256)),
        # realistic online-softmax combine shape: bm (64) is neither a
        # lane multiple nor the full row count, so the denominator must
        # ride as a [M, 1] column block, not a 1-D (bm,) vector
        ("fused_div_rowbcast/rows128x4096", "fused_div_rowbcast",
         functools.partial(drive_rowbcast, 128, 4096)),
        ("fused_softmax/rows64x1000/depth1", "fused_softmax",
         functools.partial(drive_softmax, 64, 1000, _depth_spec(1))),
        ("fused_softmax/rows64x1000/depth3", "fused_softmax",
         functools.partial(drive_softmax, 64, 1000, _depth_spec(3))),
    ]

    def drive_flash(b, c, kv, g, hd, scheme, spec=None):
        from repro.kernels.flash_attn.ops import flash_decode_attn
        flash_decode_attn(
            jnp.zeros((b, kv, g, hd), jnp.float32),
            jnp.zeros((b, c, kv, hd), jnp.float32),
            jnp.zeros((b, c, kv, hd), jnp.float32),
            jnp.zeros((b, c), jnp.int32), 0, 0, scheme,
            spec=spec, interpret=False)

    variants += [
        # decode rows scan a 256-slot cache in two 128-slot chunks with
        # the RAPID divider combine; depth3 overlaps two fetches
        ("flash_attn/decode_b2kv4c256", "flash_attn",
         functools.partial(drive_flash, 2, 256, 4, 4, 64, "rapid9")),
        ("flash_attn/decode_b2kv4c256/depth3", "flash_attn",
         functools.partial(drive_flash, 2, 256, 4, 4, 64, "rapid9",
                           _depth_spec(3))),
        # exact-divide combine, single chunk (schedules coincide w/ ref)
        ("flash_attn/decode_exact_c128", "flash_attn",
         functools.partial(drive_flash, 1, 128, 2, 8, 128, None)),
    ]

    def drive_rapid_mul():
        from repro.kernels.rapid_mul.ops import rapid_mul
        rapid_mul(jnp.arange(1000, dtype=jnp.uint32) % 997,
                  jnp.arange(1000, dtype=jnp.uint32) % 991,
                  "rapid10", n_bits=16, interpret=False)

    def drive_rapid_div():
        from repro.kernels.rapid_div.ops import rapid_div
        rapid_div(jnp.arange(513, dtype=jnp.uint32) % 255 + 1,
                  jnp.arange(513, dtype=jnp.uint32) % 15 + 1,
                  "rapid9", n_bits=8, interpret=False)

    variants += [
        ("rapid_mul/flat1000_16bit", "rapid_mul", drive_rapid_mul),
        ("rapid_div/flat513_8bit", "rapid_div", drive_rapid_div),
    ]

    # every committed tuning-cache winner (TUNE_baseline.json) audits as
    # its own tuned/<key> variant, so RPD005-008 gate the cache contents
    # — a hand-edited or stale entry fails the audit job, not a TPU run
    from repro.kernels.autotune import tuned_audit_variants
    variants += tuned_audit_variants()
    return variants


def registry_coverage() -> Dict[str, List[str]]:
    """registry family (core/backend.py) -> audited kernel families."""
    from repro.core.backend import dispatch_signature
    cover: Dict[str, List[str]] = {
        fam: [] for fam in dispatch_signature("pallas")}
    for kfam, rfam in REGISTRY_FAMILY.items():
        if rfam in cover:
            cover[rfam].append(kfam)
    return cover


def run_kernel_audit(variants: Optional[Iterable[str]] = None,
                     platform: str = "tpu"
                     ) -> Tuple[List[Finding], List[dict]]:
    """Capture + audit every kernel variant; (findings, report entries).

    Also fails (RPD007 on the pseudo-operand ``registry``) if a family
    registered in ``core/backend.py`` has no audited variant at all —
    new registry families must grow audit coverage in the same PR.
    """
    wanted = set(variants) if variants else None
    findings: List[Finding] = []
    reports: List[dict] = []
    audited_families = set()
    for vid, family, drive in iter_variants():
        if wanted and vid not in wanted:
            continue
        audited_families.add(family)
        with capture_pallas_calls() as calls:
            drive()
        if not calls:
            findings.append(Finding(
                layer="kernel", rule="RPD007", file="", line=0,
                msg="driver issued no pallas_call (wrapper rerouted off the "
                    "kernel path?)", entry=vid, primitive="kernel"))
            continue
        for i, call in enumerate(calls):
            label = vid if len(calls) == 1 else f"{vid}#{i}"
            f, rep = audit_call(call, label, family, platform)
            findings += f
            reports.append(rep)
    if wanted is None:
        for rfam, kfams in registry_coverage().items():
            if not any(k in audited_families for k in kfams):
                findings.append(Finding(
                    layer="kernel", rule="RPD007", file="", line=0,
                    msg=f"registry family {rfam!r} has no audited kernel "
                        "variant", entry="registry", primitive=rfam))
    return findings, reports


def pipeline_report_doc(reports: List[dict]) -> dict:
    """The committed PIPELINE_REPORT.json document."""
    return {
        "version": 1,
        "contract": (
            "Per-kernel pipeline legality, derived statically from "
            "captured pallas_call geometry.  Every double_buffer_safe="
            "true row must stay true: grid-staged inputs keep index "
            "maps pure functions of the grid index, manual-pipeline "
            "inputs (operands[].memory_space='any') rotate HBM slices "
            "through pipeline_depth VMEM scratch slots (scratch_bytes, "
            "already depth-deep, is included in working_set_bytes), "
            "output revisits stay on sequential dims with accumulate/"
            "first/last-guarded writes, and working_set_bytes stays "
            "inside vmem_budget_bytes at PIPELINE_BUFFERS-deep "
            "buffering."),
        "kernels": reports,
    }


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.kernel_audit",
        description="Static Pallas kernel geometry audit (layer 3)")
    ap.add_argument("--variants", default="",
                    help="comma-separated variant-id subset (default all)")
    ap.add_argument("--report", default="", metavar="PATH",
                    help="write the pipeline-legality report JSON")
    ap.add_argument("--list-variants", action="store_true")
    args = ap.parse_args(argv)
    if args.list_variants:
        for vid, family, _ in iter_variants():
            print(f"{vid}  [{family}]")
        return 0
    wanted = [v for v in args.variants.split(",") if v] or None
    findings, reports = run_kernel_audit(wanted)
    for rep in reports:
        mark = "ok " if rep["double_buffer_safe"] else "FAIL"
        print(f"{mark} {rep['variant']}: grid={tuple(rep['grid'])} "
              f"ws={rep['working_set_bytes']}B "
              f"discipline={rep['write_discipline']}")
    for f in findings:
        print(f"FINDING [{f.rule}] {f.where()}: {f.msg}")
    if args.report:
        with open(args.report, "w") as fh:
            json.dump(pipeline_report_doc(reports), fh, indent=2)
            fh.write("\n")
        print(f"pipeline report written to {args.report}")
    print(f"{len(reports)} kernel variants audited, "
          f"{len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
