"""Custom AST lint rules proving mul/div route through the RAPID registry.

The paper's end-to-end claim requires the approximate units to be
substituted in *every* kernel — a single raw ``/`` or ``@`` silently
reverts one site to exact arithmetic and over-reports the QoR/perf
tradeoff.  These rules make that class of rot visible:

  RPD001  raw matmul (``jnp.dot`` / ``@`` / ``jnp.einsum`` /
          ``lax.dot_general`` / ``jnp.matmul`` / ``jnp.tensordot`` /
          ``jnp.vdot``) outside ``core/`` + ``kernels/`` — model and
          app contractions must go through ``qmatmul`` /
          ``qmatmul_batched`` / the declared-exact ``exact_einsum``;
  RPD002  raw true-division in ``models/``, ``apps/``, ``serve/``,
          ``train/`` — divides must go through ``qdiv`` /
          ``qsoftmax_div`` / ``qrms_div`` or carry an explicit
          ``# audit: exact`` marker with a reason;
  RPD003  LUT construction (``mitchell.lut_host`` / ``lut_device`` /
          ``mul_lut_device`` / ``div_lut_device``) inside a jitted
          function body — re-baking the table per trace defeats the
          per-(scheme, dtype) memoization and bloats every executable;
  RPD004  literal backend strings (``backend="pallas"`` etc.) at call
          sites instead of ``ApproxConfig.backend_for(site)`` — a
          hard-coded name bypasses per-site routing and env/CI pinning;
  RPD009  reads of the removed ``ApproxConfig.backend`` /
          ``.matmul_backend`` aliases — both collapsed the per-site map
          to its "default" entry; the properties are gone (a read now
          raises ``AttributeError`` at runtime) and this rule is a
          **hard error**: it cannot be baselined away
          (``HARD_ERROR_RULES``), any occurrence fails the lint gate.

Marker contract: ``# audit: exact — <reason>`` on the flagged line (or
as a standalone comment on the line above) suppresses RPD rules for
that line.  The reason is mandatory — a bare marker does not suppress
(the finding's message says why).  Suppressed-with-reason escapes are
the *declared-exact* arms (accurate reference variants, host-side
constant math); everything else goes in ``AUDIT_baseline.json`` and is
burned down over time.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.findings import Finding

__all__ = [
    "RULES",
    "KERNEL_RULES",
    "HARD_ERROR_RULES",
    "MARKER_RE",
    "lint_source",
    "lint_file",
    "collect",
    "zone_of",
]

# rule id -> one-line description (the CLI prints this table)
RULES = {
    "RPD001": "raw matmul outside core/+kernels/ (use qmatmul/exact_einsum)",
    "RPD002": "raw true-division on arrays (use qdiv/qsoftmax_div/qrms_div "
              "or '# audit: exact — reason')",
    "RPD003": "LUT construction inside a jitted function (memoize via "
              "mitchell.lut_host/lut_device at trace-constant level)",
    "RPD004": "literal backend string at a call site (use "
              "ApproxConfig.backend_for(site))",
    "RPD009": "removed ApproxConfig.backend / .matmul_backend alias read "
              "(use backend_for(site); hard error, not baselineable)",
}

# Rules whose findings can never be absorbed by AUDIT_baseline.json:
# the ratchet drops any baseline entry for these before comparing, so
# even a committed occurrence fails the gate.  RPD009 graduated here
# when the runtime alias properties were deleted — a surviving read is
# an AttributeError waiting to fire, not tech debt to burn down.
HARD_ERROR_RULES = {"RPD009"}

# Layer-3 kernel-geometry rules (RPD005+), checked by
# ``repro.analysis.kernel_audit`` over captured ``pallas_call`` geometry
# rather than source text.  Kept here (pure data, no jax import) so
# ``python -m repro.analysis.lint --list-rules`` prints the whole rule
# space in one place.
KERNEL_RULES = {
    "RPD005": "VMEM working set over budget (per-grid-step tiles x "
              "pipeline buffers vs repro.kernels.budget.VMEM_BUDGET_BYTES)",
    "RPD006": "tiling misalignment (block lane dim not %128 / sublane dim "
              "not %8, or block does not divide the padded array dim)",
    "RPD007": "non-surjective index map (grid never visits a block, or "
              "maps outside the array — elements silently dropped)",
    "RPD008": "write-aliasing race (output tile revisited across a grid "
              "dim without accumulate/first/last-visit guarded writes)",
}

# package sub-dirs (zones) each rule applies to; None = every zone
_MATMUL_EXEMPT = {"core", "kernels", "analysis"}
_DIV_ZONES = {"models", "apps", "serve", "train"}
_BACKEND_ZONES = {"models", "apps", "serve", "train"}

_MATMUL_ATTRS = {"dot", "matmul", "einsum", "tensordot", "vdot",
                 "dot_general"}
_MATMUL_ROOTS = {"jnp", "jax", "lax"}
_LUT_FNS = {"lut_host", "lut_device", "mul_lut_device", "div_lut_device"}
_BACKEND_NAMES = {"jnp", "pallas", "pallas-interpret"}
# base names that conventionally hold an ApproxConfig: `<base>.backend`
# on one of these is the deprecated alias (RPD009).  `.matmul_backend`
# is unambiguous — no other type in the package carries that attribute
# — so it flags on any base.
_APPROX_BASES = {"acfg", "acfg_local", "approx", "approx_config"}

MARKER_RE = re.compile(r"#\s*audit:\s*exact\b\s*[—\-–:(]*\s*(?P<reason>.*)")


def zone_of(rel: Path) -> str:
    """First package sub-dir of a path relative to the package root
    (``src/repro``); top-level modules (compat.py) map to ``<top>``."""
    parts = rel.parts
    return parts[0] if len(parts) > 1 else "<top>"


def _dotted(node: ast.AST) -> str:
    """'jax.lax.dot_general' for an Attribute/Name chain ('' otherwise)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_const_expr(node: ast.AST) -> bool:
    """Literal-only arithmetic (``2 * 3.0``, ``-1.0``): never a traced
    array, so RPD002 skips it."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float, complex))
    if isinstance(node, ast.UnaryOp):
        return _is_const_expr(node.operand)
    if isinstance(node, ast.BinOp):
        return _is_const_expr(node.left) and _is_const_expr(node.right)
    return False


def _marker_lines(source: str) -> Dict[int, str]:
    """line -> marker reason ('' = marker present but reason missing).

    A marker on a code line covers that line; a standalone comment line
    covers the next line (so a long expression can carry the marker just
    above).  Uses tokenize so strings containing '# audit:' don't count.
    """
    markers: Dict[int, str] = {}
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return markers
    code_lines: Set[int] = set()
    for tok in tokens:
        if tok.type in (tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE,
                        tokenize.INDENT, tokenize.DEDENT,
                        tokenize.ENDMARKER):
            continue
        for ln in range(tok.start[0], tok.end[0] + 1):
            code_lines.add(ln)
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = MARKER_RE.search(tok.string)
        if not m:
            continue
        reason = m.group("reason").strip().strip(")").strip()
        ln = tok.start[0]
        target = ln if ln in code_lines else ln + 1
        markers[target] = reason
    return markers


class _Visitor(ast.NodeVisitor):
    def __init__(self, file: str, zone: str, lines: List[str]):
        self.file = file
        self.zone = zone
        self.lines = lines
        self.findings: List[Finding] = []
        self._jit_depth = 0

    # -- helpers ----------------------------------------------------------
    def _code(self, node: ast.AST) -> str:
        ln = getattr(node, "lineno", 0)
        return self.lines[ln - 1].strip() if 0 < ln <= len(self.lines) else ""

    def _emit(self, rule: str, node: ast.AST, msg: str):
        self.findings.append(Finding(
            layer="ast", rule=rule, file=self.file,
            line=getattr(node, "lineno", 0), msg=msg, code=self._code(node)))

    # -- RPD003 jit-context tracking --------------------------------------
    def _decorated_jit(self, node) -> bool:
        for dec in node.decorator_list:
            try:
                text = ast.unparse(dec)
            except Exception:  # pragma: no cover - unparse is py3.9+
                text = ""
            if re.search(r"\bjit\b", text):
                return True
        return False

    def _visit_function(self, node):
        jitted = self._decorated_jit(node)
        self._jit_depth += jitted
        self.generic_visit(node)
        self._jit_depth -= jitted

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    # -- rules -------------------------------------------------------------
    def visit_BinOp(self, node: ast.BinOp):
        if isinstance(node.op, ast.MatMult) and self.zone not in _MATMUL_EXEMPT:
            self._emit("RPD001", node,
                       "raw '@' matmul bypasses the backend registry "
                       "(route through qmatmul / exact_einsum)")
        if (isinstance(node.op, ast.Div) and self.zone in _DIV_ZONES
                and not (_is_const_expr(node.left)
                         and _is_const_expr(node.right))):
            self._emit("RPD002", node,
                       "raw '/' bypasses the RAPID divider (route through "
                       "qdiv/qsoftmax_div/qrms_div or mark "
                       "'# audit: exact — reason')")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        if isinstance(node.ctx, ast.Load):
            base = _dotted(node.value)
            base_leaf = base.rsplit(".", 1)[-1] if base else ""
            if node.attr == "matmul_backend" or (
                    node.attr == "backend" and base_leaf in _APPROX_BASES):
                self._emit(
                    "RPD009", node,
                    f"removed alias {base_leaf or '<expr>'}.{node.attr} "
                    "raises AttributeError at runtime (use "
                    "backend_for('default') or a specific site)")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        dotted = _dotted(node.func)
        root = dotted.split(".")[0] if dotted else ""
        leaf = dotted.rsplit(".", 1)[-1] if dotted else ""

        if (self.zone not in _MATMUL_EXEMPT and leaf in _MATMUL_ATTRS
                and root in _MATMUL_ROOTS):
            self._emit("RPD001", node,
                       f"raw {dotted}() bypasses the backend registry "
                       "(route through qmatmul / exact_einsum)")
        if (self.zone in _DIV_ZONES and root in ("jnp", "jax")
                and leaf in ("divide", "true_divide")):
            self._emit("RPD002", node,
                       f"raw {dotted}() bypasses the RAPID divider")
        if leaf in _LUT_FNS and self._jit_depth > 0:
            self._emit("RPD003", node,
                       f"{leaf}() inside a jitted function re-bakes the "
                       "LUT on every trace (hoist to trace-constant level)")
        if self.zone in _BACKEND_ZONES:
            for kw in node.keywords:
                if (kw.arg == "backend" and isinstance(kw.value, ast.Constant)
                        and kw.value.value in _BACKEND_NAMES):
                    self._emit(
                        "RPD004", node,
                        f"literal backend={kw.value.value!r} pins the "
                        "execution path at the call site (use "
                        "ApproxConfig.backend_for(site))")
        self.generic_visit(node)


def lint_source(source: str, file: str, zone: str) -> List[Finding]:
    """Run every rule over one module's source text."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:  # surface as a finding, not a crash
        return [Finding(layer="ast", rule="RPD000", file=file,
                        line=e.lineno or 0, msg=f"syntax error: {e.msg}")]
    lines = source.splitlines()
    visitor = _Visitor(file, zone, lines)
    visitor.visit(tree)
    markers = _marker_lines(source)
    out: List[Finding] = []
    for f in visitor.findings:
        if f.line in markers:
            if markers[f.line]:
                continue  # declared exact, with a reason
            f = Finding(**{**f.__dict__,
                           "msg": f.msg + " [marker present but missing the "
                                          "mandatory reason]"})
        out.append(f)
    return out


def lint_file(path: Path, zone: str, rel_file: Optional[str] = None
              ) -> List[Finding]:
    source = path.read_text()
    return lint_source(source, rel_file or str(path), zone)


def collect(root: Path, rel_to: Optional[Path] = None) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (the ``src/repro`` package dir).

    Findings carry paths relative to ``rel_to`` (default: two levels
    above ``root``, i.e. the repo root, so files read
    ``src/repro/...`` exactly as the committed baseline records them).
    """
    root = Path(root)
    if rel_to is None:
        rel_to = root.parent.parent if root.parent.name == "src" else root
    findings: List[Finding] = []
    for path in sorted(root.rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        rel = path.relative_to(root)
        findings += lint_file(path, zone_of(rel),
                              str(path.relative_to(rel_to)))
    return findings


def iter_rules() -> Iterable[str]:
    return iter(RULES)
