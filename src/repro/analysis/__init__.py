"""Static-analysis subsystem proving RAPID dispatch + kernel coverage.

Three layers over one report format (``findings.Finding`` + the ratchet
in ``findings.compare``):

* ``repro.analysis.rules`` / ``repro.analysis.lint`` — AST rules
  (RPD001..RPD004) over the package source; milliseconds, no jax.
* ``repro.analysis.jaxpr_audit`` — traces the registered entry points
  (model forward/decode/decode_paged, trainstep, each app core) and
  censuses ``dot_general`` / ``div`` primitives that escape the
  registry-dispatched paths, plus retrace hazards and duplicated
  large constants.
* ``repro.analysis.kernel_audit`` — captures every registered Pallas
  kernel family's ``pallas_call`` geometry (``repro.analysis.capture``,
  no TPU needed) and statically checks VMEM budget, lane/sublane
  tiling, index-map surjectivity, and output-revisit write discipline
  (RPD005..RPD008), emitting the pipeline-legality report
  (``PIPELINE_REPORT.json``) the software-pipelining work must honour.

``python -m repro.analysis`` runs all three layers and ratchets against
the committed ``AUDIT_baseline.json`` (see that file and the
quickstart's "auditing approximate-dispatch coverage" section).
"""
from repro.analysis.findings import (  # noqa: F401
    CompareResult,
    Finding,
    compare,
    dump_report,
    load_baseline,
    prune_stale,
)
from repro.analysis.rules import KERNEL_RULES, RULES  # noqa: F401
