"""Static-analysis subsystem proving RAPID dispatch coverage.

Two layers over one report format (``findings.Finding`` + the ratchet
in ``findings.compare``):

* ``repro.analysis.rules`` / ``repro.analysis.lint`` — AST rules
  (RPD001..RPD004) over the package source; milliseconds, no jax.
* ``repro.analysis.jaxpr_audit`` — traces the registered entry points
  (model forward/decode/decode_paged, trainstep, each app core) and
  censuses ``dot_general`` / ``div`` primitives that escape the
  registry-dispatched paths, plus retrace hazards and duplicated
  large constants.

``python -m repro.analysis`` runs both layers and ratchets against the
committed ``AUDIT_baseline.json`` (see that file and the quickstart's
"auditing approximate-dispatch coverage" section).
"""
from repro.analysis.findings import (  # noqa: F401
    CompareResult,
    Finding,
    compare,
    dump_report,
    load_baseline,
)
from repro.analysis.rules import RULES  # noqa: F401
