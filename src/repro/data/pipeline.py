"""Deterministic, resumable, sharded data pipeline.

Two sources:
  * ``SyntheticLM``  — seeded on (seed, step), so any step's batch can be
    regenerated exactly — restart-safe without saving cursor state;
  * ``BinCorpus``    — memory-mapped uint16/uint32 token file, strided
    into fixed-length windows; the cursor is ``step`` alone, making the
    iterator state a single int64 in the checkpoint.

Both yield *global* batches; per-host slicing for multi-process runs is a
``host_slice`` view over the global batch (process i takes rows
[i*B/nproc, (i+1)*B/nproc)) so every host touches only its shard.
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "BinCorpus", "host_slice"]


@dataclass
class SyntheticLM:
    """Zipf-ish synthetic token stream (deterministic per step)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # Zipf-like marginal over the vocab: realistic CE trajectories
        z = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1))
        toks = np.minimum(z, self.vocab_size - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class BinCorpus:
    """Flat binary token corpus, memory-mapped, strided windows."""

    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_windows = (len(self._data) - 1) // self.seq_len
        if self._n_windows < self.global_batch:
            raise ValueError(
                f"corpus {self.path} too small: {self._n_windows} windows")
        rng = np.random.default_rng(self.seed)
        self._perm = rng.permutation(self._n_windows)

    def batch_at(self, step: int) -> dict:
        idx = [
            self._perm[(step * self.global_batch + i) % self._n_windows]
            for i in range(self.global_batch)
        ]
        rows = np.stack([
            self._data[j * self.seq_len: j * self.seq_len + self.seq_len + 1]
            for j in idx
        ]).astype(np.int32)
        rows = np.minimum(rows, self.vocab_size - 1)
        return {"tokens": rows[:, :-1], "targets": rows[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def host_slice(batch: dict, process_index: int, process_count: int) -> dict:
    """Rows of the global batch owned by this host."""
    def one(x):
        b = x.shape[0]
        per = b // process_count
        return x[process_index * per:(process_index + 1) * per]

    return {k: one(v) for k, v in batch.items()}


def make_source(name: str, cfg, shape, seed: int = 0,
                path: Optional[str] = None):
    if name == "synthetic":
        return SyntheticLM(cfg.vocab_size, shape["seq_len"],
                           shape["global_batch"], seed)
    if name == "bin":
        assert path and Path(path).exists(), path
        return BinCorpus(path, cfg.vocab_size, shape["seq_len"],
                         shape["global_batch"], seed=seed)
    raise ValueError(name)
