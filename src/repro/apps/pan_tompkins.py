"""Pan-Tompkins QRS (heartbeat) detection (paper SSV-B, Fig. 5).

Stages (classic Pan-Tompkins): bandpass (cascaded LP+HP integer filters)
-> derivative -> *squaring* (multiplier kernel) -> moving-window
integration (the window mean's divide goes through the divider kernel)
-> adaptive thresholding.

Note on faithfulness: every coefficient in the PT filters is a power of
two (x2, /32, /8 ...) — in the FPGA datapath those are shifts, not
multipliers, so the filters run exactly (as in XBioSip [63]); the
approximate units are exercised where real multipliers/dividers sit: the
squaring stage and the integration mean.  QoR: QRS sensitivity/PPV
against ground truth + PSNR of the integrated signal vs the accurate
pipeline (paper gates at >= 28 dB).

ECG input is synthetic (offline container — no MIT-BIH): Gaussian-bump
P-QRS-T complexes with beat-to-beat jitter, baseline wander and noise,
with known R-peak locations.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps.arith import VARIANTS, Variant, psnr

__all__ = ["synthetic_ecg", "integrate_energy", "detect_qrs", "run", "score"]

FS = 200  # Hz, the original Pan-Tompkins design rate


def synthetic_ecg(n_beats: int = 60, seed: int = 0):
    """Returns (signal, r_peak_indices)."""
    rng = np.random.default_rng(seed)
    rr = rng.normal(0.85, 0.08, n_beats).clip(0.55, 1.3)  # seconds
    peaks = np.cumsum(rr * FS).astype(int) + FS
    n = int(peaks[-1] + 2 * FS)
    t = np.arange(n, dtype=np.float32)
    sig = np.zeros(n, np.float32)

    def bump(center, width, amp):
        # audit: exact — host-side numpy ECG synthesis, never traced
        return amp * np.exp(-0.5 * ((t - center) / width) ** 2)

    for p in peaks:
        a = rng.normal(1.0, 0.1)
        sig += bump(p - 0.04 * FS, 0.02 * FS, -0.15 * a)   # Q
        sig += bump(p, 0.012 * FS, 1.0 * a)                # R
        sig += bump(p + 0.05 * FS, 0.025 * FS, -0.2 * a)   # S
        sig += bump(p - 0.18 * FS, 0.04 * FS, 0.15 * a)    # P
        sig += bump(p + 0.3 * FS, 0.06 * FS, 0.3 * a)      # T
    # audit: exact — host-side numpy ECG synthesis, never traced
    sig += 0.1 * np.sin(2 * np.pi * 0.3 * t / FS)          # baseline wander
    sig += rng.normal(0, 0.03, n).astype(np.float32)       # noise
    return sig.astype(np.float32), peaks


def _bandpass_derivative(x: np.ndarray) -> np.ndarray:
    """PT LP+HP+derivative with power-of-two (shift) coefficients: exact."""
    n = len(x)
    lp = np.zeros(n, np.float64)
    for i in range(n):  # y = 2y1 - y2 + x - 2x6 + x12
        lp[i] = (2 * lp[i - 1] - lp[i - 2]) if i >= 2 else 0.0
        lp[i] += x[i]
        if i >= 6:
            lp[i] -= 2 * x[i - 6]
        if i >= 12:
            lp[i] += x[i - 12]
    hp = np.zeros(n, np.float64)
    for i in range(n):  # y = y1 - x/32 + x16 - x17 + x32/32
        hp[i] = hp[i - 1] if i >= 1 else 0.0
        hp[i] -= lp[i] / 32.0  # audit: exact — power-of-two shift (paper keeps filters exact)
        if i >= 16:
            hp[i] += lp[i - 16]
        if i >= 17:
            hp[i] -= lp[i - 17]
        if i >= 32:
            hp[i] += lp[i - 32] / 32.0  # audit: exact — power-of-two shift
    der = np.zeros(n, np.float64)
    for i in range(n):  # (2x + x1 - x3 - 2x4)/8
        v = 2 * hp[i]
        if i >= 1:
            v += hp[i - 1]
        if i >= 3:
            v -= hp[i - 3]
        if i >= 4:
            v -= 2 * hp[i - 4]
        der[i] = v / 8.0  # audit: exact — power-of-two shift
    return der.astype(np.float32)


def integrate_energy(der: jnp.ndarray, variant: Variant) -> jnp.ndarray:
    """jnp-only PT core (the traceable unit the dispatch auditor
    censuses): squaring through the variant multiplier, then the
    moving-window integration whose mean divide runs the divider kernel."""
    sq = variant.mul(der, der)  # squaring — the multiplier hot spot
    w = int(0.15 * FS)  # ~150 ms window
    acc = jnp.convolve(sq, jnp.ones(w, jnp.float32), mode="same")
    return variant.div(acc, jnp.full_like(acc, float(w)))


def detect_qrs(sig: np.ndarray, variant: Variant):
    """Returns (detected_peak_indices, integrated_signal)."""
    der = _bandpass_derivative(sig)
    integ = integrate_energy(jnp.asarray(der), variant)

    integ_np = np.asarray(integ)
    thr = 0.3 * np.median(np.sort(integ_np)[-max(len(integ_np) // 20, 1):])
    above = integ_np > thr
    peaks = []
    refractory = int(0.25 * FS)
    # cascade group delay: LP (12-1)/2 + HP (32-1)/2 + derivative 2 + MWI
    # peak skew — constant for the fixed filter bank
    delay = 29
    i = 0
    while i < len(above):
        if above[i]:
            j = i
            while j < len(above) and above[j]:
                j += 1
            peaks.append(max(i + int(np.argmax(integ_np[i:j])) - delay, 0))
            i = j + refractory
        else:
            i += 1
    return np.asarray(peaks), integ_np


def score(det: np.ndarray, truth: np.ndarray, tol: float = 0.1):
    """Sensitivity and positive predictivity with ±tol s matching."""
    tol_n = int(tol * FS)
    used = np.zeros(len(det), bool)
    tp = 0
    for p in truth:
        if len(det) == 0:
            break
        d = np.abs(det - p)
        j = int(np.argmin(np.where(used, 10 ** 9, d)))
        if d[j] <= tol_n and not used[j]:
            used[j] = True
            tp += 1
    fn = len(truth) - tp
    fp = len(det) - tp
    # audit: exact — host-side QoR scoring, not an approximated datapath
    return tp / max(tp + fn, 1), tp / max(tp + fp, 1)


def run(variants=("accurate", "rapid", "rapid5", "mitchell", "truncated"),
        n_beats: int = 40, seed: int = 0) -> dict:
    sig, truth = synthetic_ecg(n_beats, seed)
    _, ref_integ = detect_qrs(sig, VARIANTS["accurate"])
    out = {}
    for name in variants:
        det, integ = detect_qrs(sig, VARIANTS[name])
        se, ppv = score(det, truth)
        p = psnr(jnp.asarray(ref_integ), jnp.asarray(integ),
                 float(np.max(np.abs(ref_integ)) + 1e-9))
        out[name] = {"sensitivity": round(se, 4), "ppv": round(ppv, 4),
                     "psnr_vs_accurate_db": round(p, 2)}
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"pan-tompkins {k:10s} {v}")
