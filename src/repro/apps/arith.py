"""Shared arithmetic dispatch for the three end-to-end applications.

Each app runs under a named ``Variant`` that fixes which multiplier /
divider implementation every kernel uses — accurate, RAPID, plain
Mitchell, or the truncated DRUM/AAXD baselines — mirroring the paper's
end-to-end comparison matrix (SSV-B).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.core.truncated import aaxd_div_f32, drum_mul_f32

__all__ = ["Variant", "VARIANTS"]


@dataclass(frozen=True)
class Variant:
    name: str
    mul_kind: str  # exact | scheme name | drum
    div_kind: str  # exact | scheme name | aaxd

    def mul(self, a, b):
        if self.mul_kind == "exact":
            return a * b
        if self.mul_kind == "drum":
            return drum_mul_f32(a, b)
        return fa.approx_mul(a, b, self.mul_kind)

    def div(self, a, b):
        if self.div_kind == "exact":
            return a / b
        if self.div_kind == "aaxd":
            return aaxd_div_f32(a, b)
        return fa.approx_div(a, b, self.div_kind)

    def matmul(self, x, w):
        """Contraction built from the variant's scalar multiplier.

        x: [..., K]; w: [K, N] -> [..., N].
        """
        if self.mul_kind == "exact":
            return x @ w
        prod = self.mul(x[..., :, None], w)  # [..., K, N]
        return prod.sum(axis=-2)


VARIANTS = {
    "accurate": Variant("accurate", "exact", "exact"),
    "rapid": Variant("rapid", "rapid10", "rapid9"),
    "rapid5": Variant("rapid5", "rapid5", "rapid5"),
    "mitchell": Variant("mitchell", "mitchell", "mitchell"),
    "truncated": Variant("truncated", "drum", "aaxd"),
}


def psnr(ref: jnp.ndarray, test: jnp.ndarray, peak: float) -> float:
    mse = float(jnp.mean(jnp.square(ref.astype(jnp.float32)
                                    - test.astype(jnp.float32))))
    if mse == 0:
        return float("inf")
    return float(10.0 * jnp.log10(peak * peak / mse))
