"""Shared arithmetic dispatch for the three end-to-end applications.

Each app runs under a named ``Variant`` that fixes which multiplier /
divider implementation every kernel uses — accurate, RAPID, plain
Mitchell, or the truncated DRUM/AAXD baselines — mirroring the paper's
end-to-end comparison matrix (SSV-B).

The scheme-routed arms dispatch through the backend registry
(``repro.core.ops.qdiv`` / ``qmatmul_batched`` with the variant's
``ApproxConfig.backend_for`` selection), the same mechanism the model
zoo uses — so ``RAPID_BACKEND=pallas-interpret`` in CI drives the app
hot loops through the Pallas kernels too, and the dispatch auditor can
prove coverage.  The ``exact`` arms are the accurate reference pipeline
and are declared so (``# audit: exact``); the DRUM/AAXD arms are
truncated-baseline functions outside the log-domain registry families.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.configs.base import ApproxConfig
from repro.core import float_approx as fa
from repro.core import ops
from repro.core.truncated import aaxd_div_f32, drum_mul_f32

__all__ = ["Variant", "VARIANTS"]


@dataclass(frozen=True)
class Variant:
    name: str
    mul_kind: str  # exact | scheme name | drum
    div_kind: str  # exact | scheme name | aaxd
    # backend-registry selection for the scheme-routed arms ("auto" =
    # env var / process default / hardware, like the models)
    backends: str = "auto"

    @property
    def approx(self) -> ApproxConfig:
        """The variant as the models' config type (registry selection)."""
        mul = None if self.mul_kind in ("exact", "drum") else self.mul_kind
        div = None if self.div_kind in ("exact", "aaxd") else self.div_kind
        return ApproxConfig(mul_scheme=mul, div_scheme=div,
                            backends=self.backends)

    def _backend(self) -> str:
        return self.approx.backend_for("default")

    def mul(self, a, b):
        if self.mul_kind == "exact":
            return a * b  # audit: exact — accurate reference arm
        if self.mul_kind == "drum":
            return drum_mul_f32(a, b)
        return fa.approx_mul(a, b, self.mul_kind)

    def div(self, a, b):
        if self.div_kind == "exact":
            return a / b  # audit: exact — accurate reference arm
        if self.div_kind == "aaxd":
            return aaxd_div_f32(a, b)
        a, b = jnp.broadcast_arrays(jnp.asarray(a, jnp.float32),
                                    jnp.asarray(b, jnp.float32))
        return ops.qdiv(a, b, self.div_kind, backend=self._backend())

    def matmul(self, x, w):
        """Contraction built from the variant's multiplier.

        x: [..., K]; w: [K, N] -> [..., N].  Scheme variants route
        through the registry matmul (``qmatmul``), so the contraction
        runs the log-domain kernel the selected backend provides.
        """
        if self.mul_kind == "exact":
            return x @ w  # audit: exact — accurate reference arm
        if self.mul_kind == "drum":
            prod = self.mul(x[..., :, None], w)  # [..., K, N]
            return prod.sum(axis=-2)
        return ops.qmatmul(x, w, self.mul_kind, backend=self._backend())

    def matmul_batched(self, a, b):
        """Batched [*B, M, K] x [*B, K, N] through the variant multiplier."""
        if self.mul_kind == "exact":
            return a @ b  # audit: exact — accurate reference arm
        if self.mul_kind == "drum":
            prod = self.mul(a[..., :, :, None], b[..., None, :, :])
            return prod.sum(axis=-2)
        return ops.qmatmul_batched(a, b, self.mul_kind,
                                   backend=self._backend())


VARIANTS = {
    "accurate": Variant("accurate", "exact", "exact"),
    "rapid": Variant("rapid", "rapid10", "rapid9"),
    "rapid5": Variant("rapid5", "rapid5", "rapid5"),
    "mitchell": Variant("mitchell", "mitchell", "mitchell"),
    "truncated": Variant("truncated", "drum", "aaxd"),
}


def psnr(ref: jnp.ndarray, test: jnp.ndarray, peak: float) -> float:
    mse = float(jnp.mean(jnp.square(ref.astype(jnp.float32)
                                    - test.astype(jnp.float32))))
    if mse == 0:
        return float("inf")
    # audit: exact — host-side QoR metric, not an approximated datapath
    return float(10.0 * jnp.log10(peak * peak / mse))
