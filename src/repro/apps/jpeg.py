"""JPEG compression pipeline (paper SSV-B, Fig. 6/8).

Kernels: 8x8 blockwise 2D-DCT (butterfly-equivalent matrix form) with the
variant multiplier, quantisation with the variant *divider*, dequant with
the variant multiplier, inverse DCT.  Zigzag/Huffman are lossless and
excluded from approximation per the paper ("to remain inline with
industrial standards"); they do not affect PSNR.

Input images are procedural aerial-like terrain (offline container — no
UAV dataset), 512x512 8-bit grayscale.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps.arith import VARIANTS, Variant, psnr

__all__ = ["synthetic_aerial", "roundtrip_blocks", "jpeg_roundtrip", "run"]

# standard JPEG luminance quantisation table
QTABLE = np.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99],
], np.float32)


def _dct_matrix(n: int = 8) -> np.ndarray:
    k = np.arange(n)
    # audit: exact — host-side DCT basis constants, computed once in numpy
    c = np.sqrt(2.0 / n) * np.cos((2 * k[None, :] + 1) * k[:, None] * np.pi
                                  / (2 * n))
    c[0] /= np.sqrt(2.0)
    return c.astype(np.float32)


def synthetic_aerial(size: int = 512, seed: int = 0) -> np.ndarray:
    """Procedural terrain: multi-octave value noise + roads/field edges."""
    rng = np.random.default_rng(seed)
    img = np.zeros((size, size), np.float32)
    for octave in range(1, 6):
        n = min(2 ** octave * 4, size)
        coarse = rng.normal(size=(n, n))
        rep = -(-size // n)  # ceil: cover any size, then crop
        up = np.kron(coarse, np.ones((rep, rep)))
        img += up[:size, :size] / octave  # audit: exact — host-side numpy synthesis
    # field boundaries (straight lines) and a few bright structures
    for _ in range(12):
        o = rng.integers(0, size)
        if rng.random() < 0.5:
            img[o: o + 2, :] += 2.0
        else:
            img[:, o: o + 2] += 2.0
    for _ in range(20):
        y, x = rng.integers(16, size - 16, 2)
        img[y - 3: y + 3, x - 3: x + 3] += rng.uniform(2, 4)
    img = img - img.min()
    # audit: exact — host-side numpy image synthesis, never traced
    img = img / img.max() * 255.0
    return img.astype(np.float32)


def _blockify(img: np.ndarray, n: int = 8):
    h, w = img.shape
    return (img.reshape(h // n, n, w // n, n).transpose(0, 2, 1, 3)
            .reshape(-1, n, n))


def _unblockify(blocks: np.ndarray, h: int, w: int, n: int = 8):
    return (blocks.reshape(h // n, w // n, n, n).transpose(0, 2, 1, 3)
            .reshape(h, w))


def roundtrip_blocks(blocks: jnp.ndarray, variant: Variant,
                     q: jnp.ndarray) -> jnp.ndarray:
    """jnp-only JPEG core: DCT -> quant -> dequant -> IDCT on [N, 8, 8]
    centred blocks (the traceable unit the dispatch auditor censuses)."""
    C = jnp.asarray(_dct_matrix())

    # 2D DCT: C @ X @ C^T, both matmuls through the variant multiplier
    def mm(a, b):
        bb = jnp.broadcast_to(b, a.shape[:-2] + b.shape[-2:])
        return variant.matmul_batched(a, bb)

    coef = mm(mm(jnp.broadcast_to(C, blocks.shape[:1] + C.shape), blocks),
              C.T)
    # quantisation: the division kernel (paper: the div-included stage)
    quant = jnp.round(variant.div(coef, q[None]))
    # dequant (multiplier kernel)
    dq = variant.mul(quant, q[None])
    rec = mm(mm(jnp.broadcast_to(C.T, blocks.shape[:1] + C.shape), dq), C)
    return jnp.clip(rec + 128.0, 0, 255)


def jpeg_roundtrip(img: np.ndarray, variant: Variant,
                   quality_scale: float = 1.0) -> np.ndarray:
    """Compress + decompress with the variant's mul/div kernels."""
    q = jnp.asarray(QTABLE * quality_scale)
    blocks = jnp.asarray(_blockify(img)) - 128.0
    rec = roundtrip_blocks(blocks, variant, q)
    return np.asarray(_unblockify(np.asarray(rec), *img.shape))


def run(variants=("accurate", "rapid", "rapid5", "mitchell", "truncated"),
        n_images: int = 3, size: int = 256) -> dict:
    """PSNR of each variant vs the original images (paper Fig. 8)."""
    out = {}
    imgs = [synthetic_aerial(size, seed=s) for s in range(n_images)]
    for name in variants:
        v = VARIANTS[name]
        vals = [psnr(jnp.asarray(img),
                     jnp.asarray(jpeg_roundtrip(img, v)), 255.0)
                for img in imgs]
        out[name] = float(np.mean(vals))
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"jpeg psnr {k:10s} {v:.2f} dB")
