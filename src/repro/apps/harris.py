"""Harris Corner Detection for UAV tracking (paper SSV-B, Fig. 7/9).

Kernels: Sobel gradients (shift-coefficient filters: exact) -> gradient
products Ixx/Iyy/Ixy (multiplier) -> Gaussian window sums -> Harris
response.  The paper highlights that *division sits in the last stage* of
its HCD variant, so we use the Noble-measure form R = det / (trace + eps)
through the divider kernel.  Non-maximum suppression stays accurate
(comparisons only — paper keeps it exact).

QoR metric (paper Fig. 9): percentage of corners of the accurate pipeline
recovered by the approximate one within a 2px radius ("correct vectors";
>= 90% is the paper's acceptance bar for tracking).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.apps.arith import VARIANTS, Variant

__all__ = ["synthetic_scene", "harris_response", "harris_corners", "run"]


def synthetic_scene(size: int = 256, seed: int = 0) -> np.ndarray:
    """Blocks + rotated squares: plenty of unambiguous corners."""
    rng = np.random.default_rng(seed)
    img = rng.normal(0, 2.0, (size, size)).astype(np.float32)
    for _ in range(14):
        y, x = rng.integers(16, size - 48, 2)
        h, w = rng.integers(16, 40, 2)
        img[y: y + h, x: x + w] += rng.uniform(60, 160)
    img = np.clip(img, 0, 255)
    return img


def _sobel(img: np.ndarray):
    """Shift-coefficient Sobel (exact, like the PT filters)."""
    p = np.pad(img, 1, mode="edge").astype(np.float32)
    gx = (p[:-2, 2:] + 2 * p[1:-1, 2:] + p[2:, 2:]
          - p[:-2, :-2] - 2 * p[1:-1, :-2] - p[2:, :-2])
    gy = (p[2:, :-2] + 2 * p[2:, 1:-1] + p[2:, 2:]
          - p[:-2, :-2] - 2 * p[:-2, 1:-1] - p[:-2, 2:])
    return gx, gy


def _window_sum(x: jnp.ndarray, r: int = 2) -> jnp.ndarray:
    k = 2 * r + 1
    out = jnp.cumsum(jnp.cumsum(jnp.pad(x, ((r + 1, r), (r + 1, r))), 0), 1)
    return (out[k:, k:] - out[:-k, k:] - out[k:, :-k] + out[:-k, :-k])


def harris_response(gxj: jnp.ndarray, gyj: jnp.ndarray,
                    variant: Variant) -> jnp.ndarray:
    """jnp-only Harris core on normalized gradients (the traceable unit
    the dispatch auditor censuses): products -> window sums -> Noble
    measure through the variant divider."""
    ixx = variant.mul(gxj, gxj)
    iyy = variant.mul(gyj, gyj)
    ixy = variant.mul(gxj, gyj)
    sxx = _window_sum(ixx)
    syy = _window_sum(iyy)
    sxy = _window_sum(ixy)
    det = variant.mul(sxx, syy) - variant.mul(sxy, sxy)
    trace = sxx + syy
    return variant.div(det, trace + 1e-3)  # Noble measure — the div stage


def harris_corners(img: np.ndarray, variant: Variant, n_max: int = 200):
    gx, gy = _sobel(img)
    # audit: exact — fixed-point gradient rescale (a shift on the FPGA)
    gxj, gyj = jnp.asarray(gx) / 255.0, jnp.asarray(gy) / 255.0
    r = np.asarray(harris_response(gxj, gyj, variant))

    # accurate NMS + top-N selection (comparisons only)
    rp = np.pad(r, 1, mode="constant", constant_values=-np.inf)
    is_max = np.ones_like(r, bool)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == dx == 0:
                continue
            is_max &= r >= rp[1 + dy: 1 + dy + r.shape[0],
                              1 + dx: 1 + dx + r.shape[1]]
    cand = np.where(is_max & (r > 0.0), r, -np.inf).ravel()
    order = np.argsort(cand)[::-1][:n_max]
    order = order[np.isfinite(cand[order])]
    ys, xs = np.unravel_index(order, r.shape)
    return np.stack([ys, xs], 1)


def match_fraction(ref: np.ndarray, test: np.ndarray, tol: float = 2.0):
    if len(ref) == 0:
        return 1.0
    if len(test) == 0:
        return 0.0
    d2 = ((ref[:, None, :] - test[None, :, :]) ** 2).sum(-1)
    return float((d2.min(axis=1) <= tol * tol).mean())


def run(variants=("accurate", "rapid", "rapid5", "mitchell", "truncated"),
        n_images: int = 3, size: int = 192) -> dict:
    out = {}
    scenes = [synthetic_scene(size, seed=s) for s in range(n_images)]
    refs = [harris_corners(img, VARIANTS["accurate"]) for img in scenes]
    for name in variants:
        v = VARIANTS[name]
        fr = [match_fraction(ref, harris_corners(img, v))
              for img, ref in zip(scenes, refs)]
        out[name] = round(float(np.mean(fr)) * 100.0, 2)  # % correct vectors
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"harris correct-vectors {k:10s} {v:.1f}%")
