"""Training step factory: mixed precision, grad accumulation, sharded update.

Distributed-optimization choices visible in the lowered HLO (and therefore
in the roofline's collective term):

  * params are kept in f32 masters but *cast to the compute dtype (bf16)
    before the forward*, so every FSDP all-gather and every gradient
    reduce-scatter/all-reduce moves bf16, not f32 — half the collective
    bytes of a naive implementation;
  * gradient accumulation microbatches via ``lax.scan`` keep the weight
    collectives out of the inner loop (one reduction per step, not per
    microbatch);
  * optimizer state shards exactly like its parameter (2D FSDP x TP), so
    the update is fully local — no optimizer collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend as be
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.train.optimizer import OptConfig, make_optimizer, make_schedule

__all__ = ["make_train_step", "make_eval_step"]


def _pin_backend(model: Model, backend: Optional[str]) -> Model:
    """Resolve every site's registry backend once at step-build time.

    Pinning here (instead of per-trace inside jit) means env-var changes
    after the step is built cannot silently flip the compiled kernel
    choice between microbatches or across recompiles; an explicit
    ``backend`` name overrides every per-site entry.  On a multi-device
    TPU, auto sites pin as ``backend.AUTO_HW``: the one selection whose
    answer legitimately differs per call site (jnp for pjit-visible
    matmuls, pallas kernels inside the EP/TP shard_map bodies) — it
    re-reads only the memoized hardware probe at trace time, never the
    env var.
    """
    pinned = be.pin_backends(model.cfg.approx, backend)
    if pinned == model.cfg.approx:
        return model
    return Model(model.cfg.with_(approx=pinned))


def _cast_tree(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        tree,
    )


def make_train_step(model: Model, oc: OptConfig, ctx: ParallelCtx,
                    microbatches: int = 1, backend: Optional[str] = None):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).

    ``backend`` pins the approximate-arithmetic registry backend for the
    whole step (None = resolve from config/env/hardware)."""
    model = _pin_backend(model, backend)
    init_opt, update = make_optimizer(oc)
    sched = make_schedule(oc)
    cdt = jnp.dtype(model.cfg.dtype)

    def loss_fn(params, batch):
        return model.loss_fn(_cast_tree(params, cdt), batch, ctx)

    def constrain_grads(grads):
        # Pin gradient shardings to the parameter shardings.  Without
        # this, GSPMD can leave the scan-backward's stacked-gradient
        # accumulators replicated (9 GiB+ per mamba in_proj at Jamba
        # scale); the constraint propagates into the while-loop state.
        if ctx.mesh is None:
            return grads
        from jax.sharding import NamedSharding

        pspecs = model.pspecs(ctx.rules)
        return jax.tree.map(
            lambda g, ps: jax.lax.with_sharding_constraint(
                g, NamedSharding(ctx.mesh, ps)),
            grads, pspecs)

    def train_step(params, opt_state, batch, step):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain_grads(grads)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mbatches = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)

            def mb_step(acc, mbatch):
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.bfloat16),
                                   acc, g)
                return acc, l

            grads, losses = jax.lax.scan(mb_step, zero, mbatches)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / microbatches, grads)
            grads = constrain_grads(grads)
            loss = losses.mean()
        new_params, new_opt, gnorm = update(grads, opt_state, params, step)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": sched(step),
        }
        return new_params, new_opt, metrics

    return init_opt, train_step


def make_eval_step(model: Model, ctx: ParallelCtx,
                   backend: Optional[str] = None):
    model = _pin_backend(model, backend)
    cdt = jnp.dtype(model.cfg.dtype)

    def eval_step(params, batch):
        return model.loss_fn(_cast_tree(params, cdt), batch, ctx)

    return eval_step
