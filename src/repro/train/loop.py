"""Training loop with fault tolerance: checkpoint/restart, preemption
handling, straggler detection, loss-spike guards.

Large-scale posture (1000+ nodes):

  * **Checkpoint/restart** — periodic + on-SIGTERM checkpoints through the
    atomic CheckpointManager; resume restores step, params, optimizer and
    the data cursor (the pipeline is addressable by step, so the cursor
    *is* the step);
  * **Preemption** — SIGTERM/SIGINT set a flag read at step boundaries: a
    final checkpoint is written and the loop exits cleanly (maps to GKE /
    Borg eviction notices in production);
  * **Straggler mitigation** — per-step wall times feed an EWMA; steps
    slower than ``straggler_factor`` x EWMA are logged with their step id.
    On a real pod this hooks the coordination-service health feed to
    trigger hot-spare swap-in; here the detector + log is the testable
    part (see DESIGN.md SSFault-tolerance);
  * **Loss-spike guard** — a step whose loss exceeds ``spike_factor`` x
    running median is re-run from the previous params once (transient
    SDC / bad batch), then accepted (matches common LLM training
    practice).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager

__all__ = ["LoopConfig", "train_loop"]


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    straggler_factor: float = 3.0
    spike_factor: float = 5.0
    resume: bool = True


@dataclass
class LoopState:
    step: int = 0
    ewma_s: Optional[float] = None
    losses: list = field(default_factory=list)
    stragglers: list = field(default_factory=list)
    respun_steps: list = field(default_factory=list)


def train_loop(train_step: Callable, params, opt_state, data_source,
               lc: LoopConfig, batch_transform: Callable = lambda b: b,
               metrics_cb: Optional[Callable] = None) -> LoopState:
    """Run the loop; returns the final LoopState (metrics inside)."""
    mgr = CheckpointManager(lc.ckpt_dir, keep=lc.keep)
    state = LoopState()

    if lc.resume and mgr.latest_step() is not None:
        step, params, opt_state, extra = mgr.restore(None, params, opt_state)
        state.step = step
        print(f"[loop] resumed from checkpoint step {step}")

    stop = {"flag": False}

    def _on_term(sig, frame):
        stop["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _on_term)
        except ValueError:  # non-main thread (tests)
            pass

    try:
        while state.step < lc.total_steps and not stop["flag"]:
            t0 = time.time()
            batch = batch_transform(data_source.batch_at(state.step))
            prev = (params, opt_state)
            params, opt_state, metrics = train_step(
                params, opt_state, batch, jax.numpy.int32(state.step))
            loss = float(metrics["loss"])

            # loss-spike guard: retry once from previous state
            med = float(np.median(state.losses[-32:])) if state.losses else loss
            if (np.isfinite(med) and loss > lc.spike_factor * max(med, 1e-6)
                    and state.step not in state.respun_steps):
                state.respun_steps.append(state.step)
                params, opt_state = prev
                params, opt_state, metrics = train_step(
                    params, opt_state, batch, jax.numpy.int32(state.step))
                loss = float(metrics["loss"])
            state.losses.append(loss)

            dt = time.time() - t0
            if state.ewma_s is not None and dt > lc.straggler_factor * state.ewma_s:
                state.stragglers.append((state.step, dt))
                print(f"[loop] straggler step {state.step}: {dt:.2f}s "
                      f"(ewma {state.ewma_s:.2f}s)")
            state.ewma_s = dt if state.ewma_s is None else (
                0.9 * state.ewma_s + 0.1 * dt)

            if metrics_cb:
                metrics_cb(state.step, metrics)
            if lc.log_every and state.step % lc.log_every == 0:
                print(f"[loop] step {state.step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")

            state.step += 1
            if lc.ckpt_every and state.step % lc.ckpt_every == 0:
                mgr.save(state.step, params, opt_state,
                         extra={"data_cursor": state.step})

        if stop["flag"]:
            print(f"[loop] preemption at step {state.step}: checkpointing")
        mgr.save(state.step, params, opt_state,
                 extra={"data_cursor": state.step,
                        "preempted": stop["flag"]})
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    return state
