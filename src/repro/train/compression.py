"""Gradient compression with error feedback (int8, per-tensor scale).

The distributed-optimization trick for bandwidth-bound DP: gradients are
quantised to int8 before the data-parallel reduction and the
quantisation residual is carried into the next step (error feedback),
which keeps SGD/Adam convergence unbiased in expectation.

Two integration levels:

  * **numerics** (this module + test): `ef_compress` quantises a gradient
    tree against a carried residual tree; `ef_state` initialises the
    residuals. Composable with any optimizer.
  * **collective** level: with XLA autodiff the DP reduction is fused
    into the backward, so true wire-compression needs the manual-DP
    step (shard_map over the data axis, all_gather of int8 shards +
    local dequant-sum).  `compressed_psum` implements that primitive;
    the launchers keep bf16 reductions by default (already 2x smaller
    than f32) and expose int8 as an opt-in, since 4-bit-era compression
    trades a measurable accuracy tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ef_state", "ef_compress", "compressed_psum"]


def ef_state(params):
    """Zero residual tree matching the parameter tree."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_dequant(g: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def ef_compress(grads, residuals):
    """Quantise grads+residuals to int8 resolution; return
    (compressed_grads, new_residuals)."""
    def one(g, r):
        target = g.astype(jnp.float32) + r
        deq = _quant_dequant(target)
        return deq.astype(g.dtype), target - deq

    out = jax.tree.map(one, grads, residuals)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """int8 all-gather + local dequant-sum: a psum at 1/4 the f32 wire
    bytes (call inside shard_map over the DP axis)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    qs = jax.lax.all_gather(q, axis_name)          # [n_dev, ...] int8
    ss = jax.lax.all_gather(scale, axis_name)      # [n_dev]
    extra = (1,) * (q.ndim)
    return jnp.sum(qs.astype(jnp.float32)
                   * ss.reshape((-1,) + extra), axis=0)
