"""Sharded optimizers (AdamW, Adafactor) and LR schedules — no optax dep.

Optimizer state mirrors the parameter tree leaf-for-leaf, so the same
PartitionSpec tree shards it (ZeRO-style: moments live wherever their
parameter lives, which is already 2D-sharded under FSDP x TP).  Adafactor
is used for the 100B+ MoE models where full Adam moments would not fit
chip HBM (factored second moment: O(rows+cols) per matrix).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "make_optimizer", "make_schedule", "opt_param_specs"]


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"     # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    decay_frac: float = 0.1      # wsd: final decay fraction of total steps


def make_schedule(oc: OptConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Step -> lr multiplier * base lr."""

    def sched(step):
        step = step.astype(jnp.float32)
        # audit: exact — scalar lr-schedule math, one divide per step
        warm = jnp.minimum((step + 1.0) / jnp.maximum(oc.warmup_steps, 1), 1.0)
        if oc.schedule == "cosine":
            # audit: exact — scalar lr-schedule math, one divide per step
            t = jnp.clip((step - oc.warmup_steps)
                         / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0, 1)
            mult = 0.5 * (1 + jnp.cos(jnp.pi * t)) * 0.9 + 0.1
        elif oc.schedule == "wsd":  # warmup-stable-decay (MiniCPM)
            decay_start = oc.total_steps * (1 - oc.decay_frac)
            # audit: exact — scalar lr-schedule math, one divide per step
            t = jnp.clip((step - decay_start)
                         / jnp.maximum(oc.total_steps - decay_start, 1), 0, 1)
            mult = jnp.where(step < decay_start, 1.0, 1.0 - 0.9 * t)
        else:
            mult = 1.0
        return oc.lr * warm * mult

    return sched


def opt_param_specs(param_spec_tree, oc: OptConfig):
    """P-spec tree for the optimizer state (mirrors the parameter tree).

    Works on ``repro.models.params.P`` leaves so the dry-run can derive
    optimizer shapes + shardings without materialising anything.
    """
    from repro.models.params import P

    is_p = lambda x: isinstance(x, P)
    if oc.name == "adamw":
        zero = jax.tree.map(
            lambda p: P(p.shape, p.axes, "zeros", dtype=p.dtype), param_spec_tree,
            is_leaf=is_p)
        return {"m": zero, "v": jax.tree.map(
            lambda p: P(p.shape, p.axes, "zeros", dtype=p.dtype), param_spec_tree,
            is_leaf=is_p)}

    def one(p):
        if len(p.shape) >= 2:
            return {
                "r": P(p.shape[:-1], p.axes[:-1], "zeros"),
                "c": P(p.shape[:-2] + p.shape[-1:], p.axes[:-2] + p.axes[-1:],
                       "zeros"),
            }
        return {"v": P(p.shape, p.axes, "zeros")}

    return {"f": jax.tree.map(one, param_spec_tree, is_leaf=is_p)}


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def make_optimizer(oc: OptConfig):
    """Returns (init_fn(params)->state, update_fn(grads, state, params, step)
    -> (new_params, new_state)).  State tree leaves shard like params."""
    sched = make_schedule(oc)

    if oc.name == "adamw":
        def init(params):
            zeros = jax.tree.map(jnp.zeros_like, params)
            return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params)}

        def update(grads, state, params, step):
            gnorm = _global_norm(grads)
            # audit: exact — scalar grad-clip ratio, one divide per step
            scale = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-9))
            lr = sched(step)
            b1c = 1 - oc.b1 ** (step.astype(jnp.float32) + 1)
            b2c = 1 - oc.b2 ** (step.astype(jnp.float32) + 1)

            def upd(p, g, m, v):
                g = g.astype(jnp.float32) * scale
                m = oc.b1 * m + (1 - oc.b1) * g
                v = oc.b2 * v + (1 - oc.b2) * jnp.square(g)
                # optimizer state math stays exact f32 (stability
                # contract): only model-datapath mul/div is approximated
                # audit: exact — Adam moment normalisation (exact f32)
                step_ = (m / b1c) / (jnp.sqrt(v / b2c) + oc.eps)
                p32 = p.astype(jnp.float32)
                p32 = p32 - lr * (step_ + oc.weight_decay * p32)
                return p32.astype(p.dtype), m, v

            out = jax.tree.map(upd, params, grads, state["m"], state["v"])
            newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
            newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
            newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
            return newp, {"m": newm, "v": newv}, gnorm

        return init, update

    if oc.name == "adafactor":
        def init(params):
            def one(p):
                if p.ndim >= 2:
                    return {
                        "r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    }
                return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
            return {"f": jax.tree.map(one, params)}

        def update(grads, state, params, step):
            gnorm = _global_norm(grads)
            scale = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-9))
            lr = sched(step)
            decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

            def upd(p, g, f):
                g = g.astype(jnp.float32) * scale
                g2 = jnp.square(g) + 1e-30
                if p.ndim >= 2:
                    r = decay * f["r"] + (1 - decay) * g2.mean(axis=-1)
                    c = decay * f["c"] + (1 - decay) * g2.mean(axis=-2)
                    denom = (r[..., None] * c[..., None, :])
                    denom = denom / jnp.maximum(
                        r.mean(axis=-1)[..., None, None], 1e-30)
                    step_ = g / (jnp.sqrt(denom) + 1e-30)
                    nf = {"r": r, "c": c}
                else:
                    v = decay * f["v"] + (1 - decay) * g2
                    step_ = g / (jnp.sqrt(v) + 1e-30)
                    nf = {"v": v}
                # update clipping (Adafactor RMS rule)
                rms = jnp.sqrt(jnp.mean(jnp.square(step_)) + 1e-30)
                step_ = step_ / jnp.maximum(1.0, rms)
                p32 = p.astype(jnp.float32)
                p32 = p32 - lr * (step_ + oc.weight_decay * p32)
                return p32.astype(p.dtype), nf

            flat_p, tdef = jax.tree.flatten(params)
            flat_g = tdef.flatten_up_to(grads)
            flat_f = tdef.flatten_up_to(state["f"])
            newp, newf = [], []
            for p, g, f in zip(flat_p, flat_g, flat_f):
                np_, nf = upd(p, g, f)
                newp.append(np_)
                newf.append(nf)
            return (tdef.unflatten(newp), {"f": tdef.unflatten(newf)}, gnorm)

        return init, update

    raise ValueError(oc.name)
