"""Version-compatibility layer over the jax API surface this repo uses.

The repo targets the jax 0.4.x series that ships in the hermetic image
*and* the current 0.8+ API, which moved/renamed two things we depend on:

  * ``shard_map`` — lives at ``jax.experimental.shard_map.shard_map`` on
    0.4.x and was promoted to ``jax.shard_map`` on 0.8+;
  * the replication-check kwarg — called ``check_rep`` on 0.4.x and
    renamed to ``check_vma`` on 0.8+.

Everything that shard-maps goes through :func:`shard_map` below, which
accepts the *new* spelling (``check_vma=``) and translates to whatever
the installed jax understands.  The adapter is resolved once per process
and cached; :func:`adapt_shard_map` is the pure, cache-free core so tests
can exercise both signatures with monkeypatched implementations.
"""
from __future__ import annotations

import functools
import inspect
from typing import Callable, Optional

import jax

__all__ = ["shard_map", "adapt_shard_map", "resolve_shard_map"]


def resolve_shard_map() -> Callable:
    """Locate the installed jax's shard_map implementation."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn  # jax <= 0.4.x

    return fn


def _check_kwarg_name(impl: Callable) -> Optional[str]:
    """Which replication-check kwarg (if any) ``impl`` accepts."""
    try:
        params = inspect.signature(impl).parameters
    except (TypeError, ValueError):  # builtins / C impls: assume modern
        return "check_vma"
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return None


def adapt_shard_map(impl: Callable) -> Callable:
    """Wrap a shard_map implementation behind the 0.8+ calling convention.

    The returned callable has signature
    ``(f, *, mesh, in_specs, out_specs, check_vma=None)`` and forwards the
    check flag under whichever kwarg ``impl`` actually accepts (dropping
    it entirely for implementations that accept neither).
    """
    kwarg = _check_kwarg_name(impl)

    def call(f, *, mesh, in_specs, out_specs, check_vma=None):
        kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
        if check_vma is not None and kwarg is not None:
            kwargs[kwarg] = check_vma
        return impl(f, **kwargs)

    return call


@functools.lru_cache(maxsize=1)
def _cached_adapter() -> Callable:
    return adapt_shard_map(resolve_shard_map())


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``shard_map`` (accepts the 0.8+ ``check_vma=``)."""
    return _cached_adapter()(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=check_vma,
    )
