"""Version-compatibility layer over the jax API surface this repo uses.

The repo targets the jax 0.4.x series that ships in the hermetic image
*and* the current 0.8+ API, which moved/renamed the things we depend on:

  * ``shard_map`` — lives at ``jax.experimental.shard_map.shard_map`` on
    0.4.x and was promoted to ``jax.shard_map`` on 0.8+;
  * the replication-check kwarg — called ``check_rep`` on 0.4.x and
    renamed to ``check_vma`` on 0.8+;
  * the **named-axis environment** — the trace-time record of which mesh
    axes the current code is manually mapped over (i.e. "am I inside a
    shard_map body, and what are my local axis sizes").  Old 0.4.x
    keeps a stack of ``AxisEnvFrame``s on
    ``jax.core.thread_local_state.trace_state.axis_env``; 0.4.36+ and
    0.8+ expose a single ``get_axis_env()`` returning an ``AxisEnv``
    with an ``axis_sizes`` mapping.

Everything that shard-maps goes through :func:`shard_map` below, which
accepts the *new* spelling (``check_vma=``) and translates to whatever
the installed jax understands.  The adapter is resolved once per process
and cached; :func:`adapt_shard_map` is the pure, cache-free core so tests
can exercise both signatures with monkeypatched implementations.

Manual-mesh helpers: :func:`axis_env_sizes` / :func:`in_shard_map` /
:func:`manual_axis_size` answer the locality question the backend
registry needs — a Pallas kernel is per-device, so it is only legal on a
multi-device process when the call site is already device-local (traced
inside a shard_map body).  :func:`axis_env_reader_for` is the pure,
cache-free core over a module-like surface, so tests can exercise the
legacy-frames and modern-AxisEnv shapes against the same expectations.
"""
from __future__ import annotations

import functools
import inspect
from typing import Callable, Dict, Optional

import jax

__all__ = [
    "shard_map",
    "adapt_shard_map",
    "resolve_shard_map",
    "axis_sizes_from_env",
    "axis_sizes_from_frames",
    "axis_env_reader_for",
    "axis_env_sizes",
    "in_shard_map",
    "manual_axis_size",
]


def resolve_shard_map() -> Callable:
    """Locate the installed jax's shard_map implementation."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn  # jax <= 0.4.x

    return fn


def _check_kwarg_name(impl: Callable) -> Optional[str]:
    """Which replication-check kwarg (if any) ``impl`` accepts."""
    try:
        params = inspect.signature(impl).parameters
    except (TypeError, ValueError):  # builtins / C impls: assume modern
        return "check_vma"
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return None


def adapt_shard_map(impl: Callable) -> Callable:
    """Wrap a shard_map implementation behind the 0.8+ calling convention.

    The returned callable has signature
    ``(f, *, mesh, in_specs, out_specs, check_vma=None)`` and forwards the
    check flag under whichever kwarg ``impl`` actually accepts (dropping
    it entirely for implementations that accept neither).
    """
    kwarg = _check_kwarg_name(impl)

    def call(f, *, mesh, in_specs, out_specs, check_vma=None):
        kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
        if check_vma is not None and kwarg is not None:
            kwargs[kwarg] = check_vma
        return impl(f, **kwargs)

    return call


@functools.lru_cache(maxsize=1)
def _cached_adapter() -> Callable:
    return adapt_shard_map(resolve_shard_map())


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``shard_map`` (accepts the 0.8+ ``check_vma=``)."""
    return _cached_adapter()(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=check_vma,
    )


# --------------------------------------------------------------------------
# manual-mesh awareness: the named-axis environment, both API generations
# --------------------------------------------------------------------------

def axis_sizes_from_env(env) -> Dict[str, int]:
    """Pure: modern ``AxisEnv`` (0.4.36+/0.8+) -> ``{axis_name: size}``.

    The modern object carries an ``axis_sizes`` mapping; anything without
    one (or ``None``) reads as "no axes bound".
    """
    sizes = getattr(env, "axis_sizes", None)
    if not sizes:
        return {}
    return {name: int(size) for name, size in dict(sizes).items()
            if isinstance(name, str)}


def axis_sizes_from_frames(frames) -> Dict[str, int]:
    """Pure: legacy ``AxisEnvFrame`` stack (jax <= 0.4.35) -> sizes.

    Frames whose name is not a plain string (e.g. the ``no_axis_name``
    sentinel an unnamed vmap pushes) are skipped — only user-named mesh
    axes count as manual-region evidence.
    """
    out: Dict[str, int] = {}
    for frame in frames or ():
        name = getattr(frame, "name", None)
        size = getattr(frame, "size", None)
        if isinstance(name, str) and size is not None:
            out[name] = int(size)
    return out


def axis_env_reader_for(core) -> Callable[[], Dict[str, int]]:
    """Build an axis-size reader over a ``jax.core``-like surface.

    ``core`` exposes either the modern ``get_axis_env()`` (an ``AxisEnv``
    with ``axis_sizes``) or the legacy
    ``thread_local_state.trace_state.axis_env`` frame stack; the returned
    zero-arg callable yields ``{axis_name: size}`` either way.  Pure and
    cache-free so tests can feed both API shapes through one contract.
    """
    get_env = getattr(core, "get_axis_env", None)
    if get_env is not None:
        return lambda: axis_sizes_from_env(get_env())
    tls = getattr(core, "thread_local_state", None)
    if tls is not None:
        return lambda: axis_sizes_from_frames(tls.trace_state.axis_env)
    return dict  # no axis-env surface at all: never inside a manual region


def _installed_axis_env_reader() -> Callable[[], Dict[str, int]]:
    """Locate the installed jax's axis environment (public surface first,
    then the 0.4.36+/0.8 private home of ``get_axis_env``).

    Resolved per call — the lookup is two ``getattr``s and happens at
    trace time (not per element), and late binding keeps monkeypatched
    ``jax.core`` surfaces in tests honest.
    """
    core = jax.core
    if (getattr(core, "get_axis_env", None) is not None
            or getattr(core, "thread_local_state", None) is not None):
        return axis_env_reader_for(core)
    try:
        from jax._src import core as src_core
    except ImportError:  # pragma: no cover - unknown future jax
        return dict
    return axis_env_reader_for(src_core)


def axis_env_sizes() -> Dict[str, int]:
    """Named mesh axes bound at the current trace point -> their sizes.

    Empty outside any manually-mapped region; inside a ``shard_map``
    body it maps every mesh axis name to its mesh size (a 1-sized axis
    still counts — the body is device-local either way).
    """
    return _installed_axis_env_reader()()


def in_shard_map() -> bool:
    """Whether the current trace point is inside a manually-mapped
    (device-local) region — a ``shard_map`` body on every supported jax
    (``pmap`` and axis-named ``vmap`` also register; the repo uses
    neither).
    """
    return bool(axis_env_sizes())


def manual_axis_size(*names: str) -> int:
    """Product of the named bound axes' sizes (the local shard count
    over those axes).  Unbound names raise — asking for an axis outside
    its shard_map is a bug, not a 1.
    """
    sizes = axis_env_sizes()
    total = 1
    for name in names:
        if name not in sizes:
            raise KeyError(
                f"axis {name!r} is not bound at this trace point; "
                f"bound axes: {sorted(sizes)}")
        total *= sizes[name]
    return total
