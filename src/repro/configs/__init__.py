from repro.configs.base import (  # noqa: F401
    ARCH_IDS, SHAPES, ApproxConfig, ModelConfig, get_config, EXACT, RAPID,
)
