"""StarCoder2-7B [arXiv:2402.19173]: GQA, RoPE, LN + GELU MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152, head_dim=128,
    act="gelu", norm="ln", rope_theta=100000.0,
)
