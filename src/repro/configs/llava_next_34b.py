"""LLaVA-NeXT-34B backbone [hf:llava-hf]: Yi-34B-like decoder; anyres vision
tiling is a stub — batches carry precomputed patch embeddings (576 tokens)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava_next_34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    frontend="vision", frontend_seq=576, rope_theta=5_000_000.0,
)
