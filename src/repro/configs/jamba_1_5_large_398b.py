"""Jamba-1.5-Large 398B [arXiv:2403.19887]: Mamba+attention 1:7 interleave,
MoE (16 experts, top-2) on every other layer."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba_1_5_large_398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    n_experts=16, experts_per_token=2, moe_every=2,
    attn_every=8, ssm_state=16, ssm_conv=4, ssm_expand=2,
    optimizer="adafactor",
)
