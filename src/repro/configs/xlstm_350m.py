"""xLSTM-350M [arXiv:2405.04517]: mLSTM blocks with sparse sLSTM blocks
(approximately the paper's [7:1] ratio), no separate FFN (d_ff=0)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm_350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=256,
    slstm_at=(5, 13, 21), scan_layers=False,
)
