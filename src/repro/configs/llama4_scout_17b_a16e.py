"""Llama-4-Scout 17B-A16E [hf:meta-llama]: MoE 16 experts top-1 with an
always-on shared expert; early-fusion frontend is out of backbone scope."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4_scout_17b_a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    n_experts=16, experts_per_token=1, shared_expert=True,
    rope_theta=500000.0,
)
