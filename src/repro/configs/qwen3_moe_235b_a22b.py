"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-*]: 128 experts, top-8, per-expert
d_ff=1536."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe_235b_a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    n_experts=128, experts_per_token=8,
    rope_theta=1_000_000.0, optimizer="adafactor",
)
