"""MiniCPM-2B [arXiv:2404.06395]: llama-like, MHA, WSD LR schedule."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm_2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753, head_dim=64,
    tie_embeddings=True, lr_schedule="wsd",
)
