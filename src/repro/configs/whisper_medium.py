"""Whisper-medium backbone [arXiv:2212.04356]: enc-dec; conv frontend is a
stub — batches carry precomputed frame embeddings (assignment brief)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium", family="encdec",
    n_layers=24, n_encoder_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865, head_dim=64,
    act="gelu", norm="ln", frontend="audio", frontend_seq=1500,
)
