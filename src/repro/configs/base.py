"""Config system: model / approximation / parallelism / run configs.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` resolves them.  ``ApproxConfig``
makes the paper's technique a first-class switch on any architecture.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

ARCH_IDS = (
    "h2o_danube_1_8b",
    "yi_6b",
    "minicpm_2b",
    "starcoder2_7b",
    "whisper_medium",
    "xlstm_350m",
    "jamba_1_5_large_398b",
    "qwen3_moe_235b_a22b",
    "llama4_scout_17b_a16e",
    "llava_next_34b",
)

# canonical input-shape set for the LM family (assignment brief)
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


# Sites that can carry their own backend-registry override: the matmul
# sites (mlp / attn_proj / logits) and the divider sites (norm /
# softmax).  "default" is the fallback entry every site defers to.
BACKEND_SITES = ("mlp", "attn_proj", "logits", "norm", "softmax")


def _canon_backends(backends) -> Tuple[Tuple[str, str], ...]:
    """Canonicalize a site->backend spec to sorted hashable pairs.

    Accepts a plain registry name (applied as the default for every
    site), a mapping over ``BACKEND_SITES`` + "default", or the already-
    canonical tuple-of-pairs form.  Unknown site keys raise.
    """
    if isinstance(backends, str):
        return (("default", backends),)
    pairs = dict(backends)
    unknown = set(pairs) - set(BACKEND_SITES) - {"default"}
    if unknown:
        raise KeyError(
            f"unknown backend sites {sorted(unknown)}; have "
            f"{BACKEND_SITES + ('default',)}")
    pairs.setdefault("default", "auto")
    return tuple(sorted(pairs.items()))


@dataclass(frozen=True)
class ApproxConfig:
    """Where and how the RAPID units replace exact arithmetic."""

    mul_scheme: Optional[str] = None   # None/"exact" | "mitchell" | "rapid3/5/10"
    div_scheme: Optional[str] = None   # None/"exact" | "mitchell" | "rapid3/5/9"
    # which matmuls route through the logarithmic multiplier
    on_mlp: bool = True
    on_attn_proj: bool = True
    on_logits: bool = False
    # which divisions route through the logarithmic divider
    on_softmax: bool = True
    on_norm: bool = True
    # site -> backend-registry name (repro.core.backend) for every
    # routed op — matmuls and the whole divider family alike.  Accepts a
    # plain name ("every site"), a mapping over BACKEND_SITES +
    # "default", or canonical tuple-of-pairs; each entry is "auto"
    # (resolve via env var / process default / hardware autodetect) or
    # an explicit registry name ("jnp" | "pallas" | "pallas-interpret").
    # Per-site entries let one model mix execution paths — e.g. pallas
    # fused-tail MLPs with partitioner-visible jnp logits.  A config
    # pinned at engine/trainstep build (ModelConfig.with_backend /
    # core.backend.pin_backends) therefore reaches every site; on a
    # multi-device TPU a pinned auto site holds backend.AUTO_HW — the
    # deliberately context-dependent entry that resolves to jnp from
    # the global (pjit) view but to the pallas kernels inside shard_map
    # bodies, where the call is already device-local.
    backends: object = "auto"

    def __post_init__(self):
        object.__setattr__(self, "backends", _canon_backends(self.backends))

    @property
    def active(self) -> bool:
        return self.mul_scheme not in (None, "exact") or self.div_scheme not in (
            None,
            "exact",
        )

    def mul(self, site: str) -> Optional[str]:
        if self.mul_scheme in (None, "exact"):
            return None
        return self.mul_scheme if getattr(self, f"on_{site}") else None

    def div(self, site: str) -> Optional[str]:
        if self.div_scheme in (None, "exact"):
            return None
        return self.div_scheme if getattr(self, f"on_{site}") else None

    def backend_for(self, site: str) -> str:
        """Backend-registry name for one site ("default" = the fallback).

        A site whose entry is absent *or* "auto" defers to the "default"
        entry; "auto" there defers further to env/process-default/
        hardware (see ``repro.core.backend.resolve_backend_name``).
        """
        if site != "default" and site not in BACKEND_SITES:
            raise KeyError(
                f"unknown backend site {site!r}; have {BACKEND_SITES}")
        table = dict(self.backends)
        name = table.get(site)
        if site != "default" and name in (None, "auto"):
            name = table.get("default")
        return name or "auto"

    def with_backends(self, backends) -> "ApproxConfig":
        """Merge a site->backend mapping (a plain name resets all sites)."""
        if isinstance(backends, str):
            return dataclasses.replace(self, backends=backends)
        merged = dict(self.backends)
        merged.update(dict(backends))  # __post_init__ re-validates keys
        return dataclasses.replace(self, backends=merged)

    # The one-release ``.backend`` / ``.matmul_backend`` read-alias
    # properties are gone: read sites through :meth:`backend_for`,
    # construct/replace with ``backends=`` or :meth:`with_backends`.
    # Lint rule RPD009 hard-errors on any remaining alias read.


EXACT = ApproxConfig()
RAPID = ApproxConfig(mul_scheme="rapid10", div_scheme="rapid9")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "silu"  # silu (swiglu) | gelu (plain 2-matrix mlp)
    norm: str = "rms"  # rms | ln
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    sliding_window: int = 0  # 0 = full attention
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1          # MoE FFN on every k-th layer (jamba: 2)
    shared_expert: bool = False  # llama4-style always-on expert
    capacity_factor: float = 1.25
    # --- hybrid (jamba) ---
    attn_every: int = 0         # 1 attention layer per this many (jamba: 8)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- xlstm ---
    slstm_at: Tuple[int, ...] = ()  # block indices using sLSTM (rest mLSTM)
    # --- encoder-decoder / multimodal frontends ---
    n_encoder_layers: int = 0
    frontend: str = ""          # "" | "audio" | "vision"
    frontend_seq: int = 0       # encoder frames / image patch tokens
    # --- numerics / approximation ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    approx: ApproxConfig = field(default_factory=ApproxConfig)
    # --- training-time ---
    remat: str = "block"        # none | block | full
    scan_layers: bool = True
    optimizer: str = "adamw"    # adamw | adafactor (huge MoE)
    lr_schedule: str = "cosine"  # cosine | wsd (minicpm)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 256 so the vocab
        dim shards evenly (standard practice; padded ids are never
        targets).  Odd real vocabs: minicpm 122753, whisper 51865."""
        return -(-self.vocab_size // 256) * 256

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_backend(self, backend: str) -> "ModelConfig":
        """Pin one approximate-arithmetic backend for every site."""
        return self.with_(approx=self.approx.with_backends(backend))

    def with_site_backends(self, backends) -> "ModelConfig":
        """Merge per-site backend overrides (see ApproxConfig.backends),
        e.g. ``cfg.with_site_backends({"mlp": "pallas", "logits": "jnp"})``."""
        return self.with_(approx=self.approx.with_backends(backends))

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if not self.attn_every else self.attn_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            frontend_seq=min(self.frontend_seq, 8) if self.frontend_seq else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token
            else 0,
            slstm_at=tuple(i for i in self.slstm_at if i < 2),
            scan_layers=False,
            remat="none",
        )
        if self.attn_every:
            kw["n_layers"] = self.attn_every  # one full hybrid period
        return self.with_(**kw)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG
