"""Logical->physical sharding rules for every mesh / workload combination.

Axis menu (logical names used by model param/cache specs):

  batch      activation batch                -> ("pod","data") / ("data",)
  embed      d_model rows of weight matrices -> "data" (FSDP) or None
  ff         mlp hidden / fused head dim     -> "model" (TP)
  heads      attention head output dim       -> "model" (TP)
  kv         kv head dim                     -> None (small; replicated)
  vocab      embedding/vocab dim             -> "model" (TP)
  expert     MoE expert dim                  -> "model" (EP == TP, no extra
                                                collective vs dense TP)
  expert_ff  per-expert ff dim               -> "data" (FSDP at rest,
                                                gathered inside the layer)
  seq        decode-cache length             -> "data" (flash-decode) when
                                                the cell's batch is 1
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig

__all__ = ["make_rules", "named_sharding_tree", "batch_pspec"]


def make_rules(cfg: Optional[ModelConfig] = None, *, multi_pod: bool = False,
               fsdp: bool = True, shard_cache_seq: bool = False,
               seq_parallel: bool = True, shard_batch: bool = True,
               pure_dp: bool = False) -> dict:
    # batch=1 cells (long-context decode) cannot shard the batch axis;
    # the cache length shards on "data" instead (flash-decode).
    batch = (("pod", "data") if multi_pod else ("data",)) if shard_batch else None
    rules = {
        "batch": batch,
        "embed": "data" if fsdp else None,
        "ff": "model",
        "heads": "model",
        "kv": None,
        "vocab": "model",
        "expert": "model",
        "expert_ff": "data" if fsdp else None,
        # decode KV caches shard their length on the TP axis (flash-
        # decode combine via pmax/psum) — avoids replicating 100s of GB
        # of cache on archs whose kv-head count cannot shard 16-way
        "seq": "model" if shard_cache_seq else None,
        # Megatron-style sequence parallelism: activations at block
        # boundaries shard S over the TP axis -> 16x smaller saved
        # carries under remat-scan, and AG+RS replaces AR around TP
        # regions (same volume, but exposes overlap).
        "seq_act": "model" if seq_parallel else None,
        "embed2": None,
        "act_embed": None,
    }
    if pure_dp:
        # Dense models <= ~35B over-parallelise at 16-way TP: the per-layer
        # activation AG+RS dominates the roofline.  When the global batch
        # divides the chip count, run pure DP/FSDP instead: both mesh axes
        # carry batch, weights shard over "data" and are all-gathered
        # just-in-time (bf16) — measured 7.4x lower collective time on
        # yi-6b train_4k (EXPERIMENTS.md SSPerf).
        rules.update({
            "batch": ("pod", "data", "model") if multi_pod else ("data", "model"),
            "ff": None, "heads": None, "vocab": None, "seq_act": None,
            "expert": None, "expert_ff": "data", "embed": "data",
        })
    return rules


def named_sharding_tree(mesh, pspec_tree):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), pspec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def batch_pspec(rules: dict, ndim: int = 2) -> PartitionSpec:
    """[B, S, ...] batch sharding (batch axis only)."""
    return PartitionSpec(rules["batch"], *(None,) * (ndim - 1))
