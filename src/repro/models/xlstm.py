"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM keeps a per-head matrix memory C [hd, hd] with exponential input
gates and sigmoid-in-log-space forget gates.  Training/prefill run the
*chunkwise* form: intra-chunk quadratic attention-like scores with decay
weights, inter-chunk state carried by ``lax.scan`` — everything
stabilised by a running log-scale ``m`` so no exp overflows (the carry is
``(C*exp(-m), n*exp(-m), m)``).  Decode is the O(1) recurrence.

sLSTM is genuinely sequential (recurrent h -> gate connections), so it
runs as a time-step ``lax.scan`` — the assignment's xlstm-350m places it
in a minority of blocks (cfg.slstm_at).

Note the xLSTM output normaliser ``h = num / max(|n.q|, 1)`` is a real
*division* in the hot path — it routes through the RAPID divider when
enabled (site "norm").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.ops import qdiv
from repro.models.layers import ParallelCtx, dense
from repro.models.params import P

__all__ = [
    "mlstm_params", "mlstm", "mlstm_decode", "mlstm_init_cache",
    "slstm_params", "slstm", "slstm_decode", "slstm_init_cache",
]

_CHUNK = 64


def _norm_div(num, den, acfg):
    # registry-routed with the per-site "norm" backend override so an
    # engine/trainstep-pinned backend reaches the xLSTM normalisers too
    # (approx_div bypassed the registry and silently stayed on jnp)
    sch = acfg.div("norm")
    if sch:
        return qdiv(num, den, sch, backend=acfg.backend_for("norm"))
    return num / den


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_dims(cfg: ModelConfig):
    """mLSTM operates in the 2x up-projected space; heads split that."""
    up = 2 * cfg.d_model
    return up, up // cfg.n_heads


def mlstm_params(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    up, hd = mlstm_dims(cfg)
    return {
        "up_proj": P((D, 2 * up), ("embed", "ff")),
        "wq": P((up, H * hd), ("embed", "heads")),
        "wk": P((up, H * hd), ("embed", "heads")),
        "wv": P((up, H * hd), ("embed", "heads")),
        "wi": P((up, H), ("ff", None), "small"),
        "wf": P((up, H), ("ff", None), "small"),
        "f_bias": P((H,), (None,), "ones", 3.0),
        "down_proj": P((up, D), ("heads", "embed")),
    }


def _gates(xi, params):
    li = jnp.einsum("...u,uh->...h", xi, params["wi"].astype(jnp.float32))
    lf = jax.nn.log_sigmoid(
        jnp.einsum("...u,uh->...h", xi, params["wf"].astype(jnp.float32))
        + 3.0 * params["f_bias"].astype(jnp.float32)
    )
    return li, lf  # log input gate (unbounded), log forget gate (<0)


def _mlstm_core_chunk(q, k, v, li, lf, carry, acfg):
    """One chunk. q,k,v: [B,H,L,hd]; li,lf: [B,H,L]; carry (Ch,nh,m)."""
    B, H, L, hd = q.shape
    Ch, nh, m0 = carry  # Ch: [B,H,hd,hd] (k x v), nh: [B,H,hd], m0: [B,H]
    F = jnp.cumsum(lf, axis=-1)                     # [B,H,L]
    b = li - F                                      # log(i) - F
    M = jnp.maximum(jax.lax.cummax(b, axis=2), m0[..., None])
    m_t = F + M                                     # stabiliser per step
    # intra-chunk scores
    qs = q.astype(jnp.float32) / jnp.sqrt(hd)
    s = jnp.einsum("bhld,bhtd->bhlt", qs, k.astype(jnp.float32))
    w = F[..., :, None] + b[..., None, :] - m_t[..., :, None]  # [B,H,L,L]
    mask = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(mask, w, -jnp.inf)
    p = jnp.exp(w)
    num = jnp.einsum("bhlt,bhtd->bhld", p * s, v.astype(jnp.float32))
    den = (p * s).sum(axis=-1)                      # [B,H,L]
    # inter-chunk (state) contribution
    w_st = jnp.exp(F + m0[..., None] - m_t)         # [B,H,L]
    num = num + w_st[..., None] * jnp.einsum("bhld,bhde->bhle", qs, Ch)
    den = den + w_st * jnp.einsum("bhld,bhd->bhl", qs, nh)
    h = _norm_div(num, jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None], acfg)
    # carry update
    mL = m_t[..., -1]
    wc = jnp.exp(F[..., -1] + m0 - mL)              # carry decay
    wk_ = jnp.exp(F[..., -1:] + b - mL[..., None])  # F_L - F_tau + li_tau, stabilised
    Ch = wc[..., None, None] * Ch + jnp.einsum(
        "bhl,bhld,bhle->bhde", wk_, k.astype(jnp.float32), v.astype(jnp.float32)
    )
    nh = wc[..., None] * nh + jnp.einsum("bhl,bhld->bhd", wk_, k.astype(jnp.float32))
    return h, (Ch, nh, mL)


def _split_heads(x, H):
    B, S, _ = x.shape
    return x.reshape(B, S, H, -1).transpose(0, 2, 1, 3)  # [B,H,S,hd]


def mlstm(x, params, cfg: ModelConfig, ctx: ParallelCtx):
    """Train/prefill. x: [B,S,D] -> ([B,S,D], cache)."""
    B, S, D = x.shape
    H = cfg.n_heads
    acfg = cfg.approx
    up2 = dense(x, params["up_proj"], acfg, "mlp")
    xi, z = jnp.split(up2, 2, axis=-1)
    xi = ctx.shard(xi, "batch", None, "ff")
    xif = xi.astype(jnp.float32)

    q = _split_heads(dense(xi, params["wq"], acfg, "attn_proj"), H)
    k = _split_heads(dense(xi, params["wk"], acfg, "attn_proj"), H)
    v = _split_heads(dense(xi, params["wv"], acfg, "attn_proj"), H)
    li, lf = _gates(xif, params)
    li = li.transpose(0, 2, 1)  # [B,H,S]
    lf = lf.transpose(0, 2, 1)

    L = min(_CHUNK, S)
    pad = (-S) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)), constant_values=-1e9)
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))
    steps = (S + pad) // L
    _, hd = mlstm_dims(cfg)

    def resh(t):
        return t.reshape(B, H, steps, L, -1).transpose(2, 0, 1, 3, 4)

    qs, ks, vs = resh(q), resh(k), resh(v)
    lis = li.reshape(B, H, steps, L).transpose(2, 0, 1, 3)
    lfs = lf.reshape(B, H, steps, L).transpose(2, 0, 1, 3)

    def step(carry, xs):
        qc, kc, vc, lic, lfc = xs
        h, carry = _mlstm_core_chunk(qc, kc, vc, lic, lfc, carry, acfg)
        return carry, h

    carry0 = mlstm_init_cache(cfg, B)
    carry, hs = jax.lax.scan(step, carry0, (qs, ks, vs, lis, lfs))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, steps * L, hd)[:, :, :S]
    h = h.transpose(0, 2, 1, 3).reshape(B, S, H * hd)

    out = h.astype(x.dtype) * jax.nn.silu(z)
    out = dense(out, params["down_proj"], acfg, "mlp")
    return ctx.shard(out, "batch", "seq_act", "act_embed"), carry


def mlstm_init_cache(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    _, hd = mlstm_dims(cfg)
    return (
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, H, hd), jnp.float32),
        jnp.full((batch, H), -1e30, jnp.float32),
    )


def mlstm_decode(x, cache, params, cfg: ModelConfig, ctx: ParallelCtx):
    """One token. x: [B,D]; cache (Ch, nh, m)."""
    B, D = x.shape
    H = cfg.n_heads
    _, hd = mlstm_dims(cfg)
    acfg = cfg.approx
    Ch, nh, m0 = cache

    up2 = dense(x[:, None], params["up_proj"], acfg, "mlp")
    xi, z = jnp.split(up2, 2, axis=-1)
    xif = xi.astype(jnp.float32)
    q = dense(xi, params["wq"], acfg, "attn_proj").reshape(B, H, hd)
    k = dense(xi, params["wk"], acfg, "attn_proj").reshape(B, H, hd)
    v = dense(xi, params["wv"], acfg, "attn_proj").reshape(B, H, hd)
    li, lf = _gates(xif[:, 0], params)  # [B,H]

    m_t = jnp.maximum(lf + m0, li)
    wf = jnp.exp(lf + m0 - m_t)
    wi = jnp.exp(li - m_t)
    kf = k.astype(jnp.float32)
    Ch = wf[..., None, None] * Ch + wi[..., None, None] * (
        kf[..., :, None] * v.astype(jnp.float32)[..., None, :]
    )
    nh = wf[..., None] * nh + wi[..., None] * kf
    qf = q.astype(jnp.float32) / jnp.sqrt(hd)
    num = jnp.einsum("bhd,bhde->bhe", qf, Ch)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, nh))
    h = _norm_div(num, jnp.maximum(den, jnp.exp(-m_t))[..., None], acfg)
    h = h.reshape(B, H * hd).astype(x.dtype) * jax.nn.silu(z[:, 0])
    out = dense(h[:, None], params["down_proj"], acfg, "mlp")[:, 0]
    return out, (Ch, nh, m_t)


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_params(cfg: ModelConfig) -> dict:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "w": P((D, 4 * H * hd), ("embed", "heads")),
        "r": P((H, hd, 4 * hd), (None, None, None), "normal", 0.5),
        "bias": P((4 * H * hd,), (None,), "zeros"),
        "down_proj": P((H * hd, D), ("heads", "embed")),
    }


def slstm_init_cache(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.hd
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return (z, z, jnp.full((batch, H, hd), -1e30, jnp.float32), z)  # c, n, m, h


def _slstm_step(params, cfg, acfg, carry, wx_t):
    """wx_t: [B, 4*H*hd] precomputed input projection at step t."""
    c, n, m, h = carry
    H, hd = cfg.n_heads, cfg.hd
    B = wx_t.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", h, params["r"].astype(jnp.float32))
    pre = wx_t.reshape(B, H, 4 * hd).astype(jnp.float32) + rec \
        + params["bias"].astype(jnp.float32).reshape(H, 4 * hd)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    zt = jnp.tanh(zt)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(lf + m - m_new)
    c = f_p * c + i_p * zt
    n = f_p * n + i_p
    h_new = jnp.tanh(_norm_div(c, jnp.maximum(n, 1e-6), acfg))
    o = jax.nn.sigmoid(ot)
    h_new = o * h_new
    return (c, n, m_new, h_new), h_new


def slstm(x, params, cfg: ModelConfig, ctx: ParallelCtx):
    """Sequential scan over time. x: [B,S,D]."""
    B, S, D = x.shape
    acfg = cfg.approx
    wx = dense(x, params["w"], acfg, "mlp")  # [B,S,4*H*hd]

    def step(carry, wx_t):
        return _slstm_step(params, cfg, acfg, carry, wx_t)

    carry0 = slstm_init_cache(cfg, B)
    carry, hs = jax.lax.scan(step, carry0, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, -1).astype(x.dtype)
    out = dense(h, params["down_proj"], acfg, "mlp")
    return ctx.shard(out, "batch", "seq_act", "act_embed"), carry


def slstm_decode(x, cache, params, cfg: ModelConfig, ctx: ParallelCtx):
    acfg = cfg.approx
    wx = dense(x[:, None], params["w"], acfg, "mlp")[:, 0]
    carry, h = _slstm_step(params, cfg, acfg, cache, wx)
    out = dense(h.reshape(x.shape[0], -1)[:, None].astype(x.dtype),
                params["down_proj"], acfg, "mlp")[:, 0]
    return out, carry
