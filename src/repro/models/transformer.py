"""Decoder transformer block (dense or MoE FFN) + KV-cache decode step."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.layers import (
    ParallelCtx,
    apply_norm,
    attention_params,
    decode_attention,
    dense,
    mlp,
    mlp_params,
    norm_params,
    rope,
)
from repro.models.params import P

__all__ = [
    "block_params",
    "block_apply",
    "block_decode",
    "block_decode_paged",
    "attn_cache_specs",
    "paged_attn_cache_specs",
    "cross_attention_block",
]


def block_params(cfg: ModelConfig, moe_layer: bool = False,
                 norm_kind: str = "rms", cross: bool = False) -> dict:
    p = {
        "ln1": norm_params(cfg, norm_kind),
        "attn": attention_params(cfg),
        "ln2": norm_params(cfg, norm_kind),
    }
    if cross:
        p["lnx"] = norm_params(cfg, norm_kind)
        p["xattn"] = attention_params(cfg, cross=True)
    p["ffn"] = moe_mod.moe_params(cfg) if moe_layer else mlp_params(cfg)
    return p


def _ffn(x, p, cfg, ctx, moe_layer, residual=None):
    if moe_layer:
        out = moe_mod.moe_ffn(x, p["ffn"], cfg, ctx)
        return out if residual is None else residual + out
    return mlp(x, p["ffn"], cfg, ctx, residual=residual)


def block_apply(x, p, cfg: ModelConfig, ctx: ParallelCtx, positions,
                moe_layer: bool = False, norm_kind: str = "rms",
                enc_out=None, enc_positions=None, causal: bool = True,
                return_kv: bool = False):
    """Full-sequence block. Returns (x, kv) where kv=(k, v) if requested.

    The block tail is fused: the residual adds ride the attention-out /
    MLP down-projection matmul epilogues, and — for rms-normed blocks
    without a cross-attention slot in between — ln2's normalization
    division is fused into the attention-out matmul's epilogue too
    (``rms_div(wo_out + residual)`` with the RAPID divider on the
    VMEM-resident output tile; only the cheap ``* scale`` stays outside).
    """
    from repro.models.layers import attention

    x = ctx.shard(x, "batch", "seq_act", None)
    # ln2's rms-div fuses into the attention-out matmul only when both
    # sites route to the same backend — a per-site "norm" override must
    # keep steering the normalization divide, not be silently absorbed
    # into the attn_proj matmul's execution path
    acfg = cfg.approx
    fuse_ln2 = (norm_kind == "rms" and enc_out is None
                and acfg.backend_for("norm") == acfg.backend_for("attn_proj"))
    h, k, v = attention(
        apply_norm(x, p["ln1"], cfg, norm_kind), p["attn"], cfg, ctx, positions,
        causal=causal, residual=x, tail_norm=fuse_ln2,
    )
    if fuse_ln2:
        y, ydiv = h
        ffn_in = (ydiv.astype(jnp.float32)
                  * p["ln2"]["scale"].astype(jnp.float32)).astype(y.dtype)
    else:
        y = h
        if enc_out is not None:
            hx, _, _ = attention(
                apply_norm(y, p["lnx"], cfg, norm_kind), p["xattn"], cfg, ctx,
                positions, kv_x=enc_out, kv_positions=enc_positions,
                causal=False, residual=y,
            )
            y = hx
        ffn_in = apply_norm(y, p["ln2"], cfg, norm_kind)
    x = _ffn(ffn_in, p, cfg, ctx, moe_layer, residual=y)
    return (x, (k, v)) if return_kv else (x, None)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def attn_cache_specs(cfg: ModelConfig, batch: int, seq_len: int,
                     cross_len: int = 0) -> dict:
    """P-spec tree for one layer's attention cache."""
    C = cache_len(cfg, seq_len)
    KV, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.dtype
    cache_batch_ax = "batch" if batch > 1 else None
    # cache length always carries the "seq" logical axis: kv-head counts
    # (4..36) can never shard a 16-way axis, the 32k length always can
    seq_ax = "seq"
    p = {
        "k": P((batch, C, KV, hd), (cache_batch_ax, seq_ax, "kv", None),
               "zeros", dtype=dt),
        "v": P((batch, C, KV, hd), (cache_batch_ax, seq_ax, "kv", None),
               "zeros", dtype=dt),
    }
    if cross_len:
        p["ck"] = P((batch, cross_len, KV, hd), (cache_batch_ax, None, "kv", None),
                    "zeros", dtype=dt)
        p["cv"] = P((batch, cross_len, KV, hd), (cache_batch_ax, None, "kv", None),
                    "zeros", dtype=dt)
    return p


def paged_attn_cache_specs(cfg: ModelConfig, n_pages: int,
                           page_size: int) -> dict:
    """P-spec tree for one layer's block-paged KV pool.

    Unlike :func:`attn_cache_specs` there is no batch dim: slots own
    pages of the shared ``[n_pages, page_size, KV, hd]`` pool through a
    page table, so memory scales with live tokens, not slots x cache_n.
    """
    KV, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.dtype
    return {
        "k": P((n_pages, page_size, KV, hd), (None, None, "kv", None),
               "zeros", dtype=dt),
        "v": P((n_pages, page_size, KV, hd), (None, None, "kv", None),
               "zeros", dtype=dt),
    }


def block_decode_paged(x, p, cache, page_table, positions, valid,
                       kv_len, cfg: ModelConfig, ctx: ParallelCtx,
                       moe_layer: bool = False, norm_kind: str = "rms"):
    """Chunk decode against a block-paged KV pool (page-table writes).

    The paged generalization of :func:`block_decode`'s ring write: token
    ``i`` of slot ``b`` lands in pool page ``page_table[b, pos // PS]``
    at offset ``pos % PS``, and the slot's cache view is gathered back
    through the same table.  Handles both the continuous decode step
    (S=1, all slots) and a chunked-prefill step (S=chunk, one slot).

    x: [B, S, D]; cache: {"k","v"} pools [NP, PS, KV, hd]; page_table:
    [B, P] int32 pool indices; positions: [B, S] absolute token
    positions; valid: [B, S] bool (False tokens write to the scratch
    page and their outputs are ignored); kv_len: [B] int32 valid cache
    tokens per slot *after* this chunk's writes.
    """
    from repro.models.layers import chunk_cache_attention, decode_attention

    acfg = cfg.approx
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    PS = cache["k"].shape[1]
    Pp = page_table.shape[1]
    C = Pp * PS

    h = apply_norm(x, p["ln1"], cfg, norm_kind)
    q = dense(h, p["attn"]["wq"], acfg, "attn_proj").reshape(B, S, H, hd)
    k = dense(h, p["attn"]["wk"], acfg, "attn_proj").reshape(B, S, KV, hd)
    v = dense(h, p["attn"]["wv"], acfg, "attn_proj").reshape(B, S, KV, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    # page-table indirection write; invalid tokens go to the scratch
    # page (0), whose contents are never addressed by any page table
    # audit: exact — integer page-index arithmetic, not datapath
    pidx = jnp.clip(positions // PS, 0, Pp - 1)           # [B, S]
    pid = jnp.take_along_axis(page_table, pidx, axis=1)   # [B, S]
    pid = jnp.where(valid, pid, 0).reshape(-1)
    poff = (positions % PS).reshape(-1)
    ck = cache["k"].at[pid, poff].set(
        k.reshape(B * S, KV, hd).astype(cache["k"].dtype))
    cv = cache["v"].at[pid, poff].set(
        v.reshape(B * S, KV, hd).astype(cache["v"].dtype))

    # gather the slot views back through the table: [B, P*PS, KV, hd]
    kg = ck[page_table].reshape(B, C, KV, hd)
    vg = cv[page_table].reshape(B, C, KV, hd)
    j = jnp.arange(C, dtype=jnp.int32)
    kv_pos = jnp.where(j[None, :] < kv_len[:, None], j[None, :],
                       jnp.iinfo(jnp.int32).max)          # [B, C]

    if S == 1:
        # the hot path: same formulation as the dense decode step, so a
        # paged slot's logits are bit-identical to a lockstep slot's
        attn_out = decode_attention(
            q[:, 0], kg, vg, kv_pos, positions[:, 0], cfg.sliding_window,
            acfg, ctx)[:, None]
    else:
        attn_out = chunk_cache_attention(
            q, kg, vg, positions, kv_pos, cfg.sliding_window, acfg)
    x = dense(attn_out, p["attn"]["wo"], acfg, "attn_proj",
              residual=x)
    h2 = apply_norm(x, p["ln2"], cfg, norm_kind)
    x = _ffn(h2, p, cfg, ctx, moe_layer, residual=x)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ck, cv
    return x, new_cache


def block_decode(x, p, cache, slot_positions, pos, cfg: ModelConfig,
                 ctx: ParallelCtx, moe_layer: bool = False,
                 norm_kind: str = "rms", enc_positions=None,
                 seq_shard_axis: Optional[str] = None):
    """One-token decode. x: [B, D]; cache: {"k","v"[,ck,cv]}; pos scalar."""
    acfg = cfg.approx
    B, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    C = cache["k"].shape[1]

    h = apply_norm(x[:, None], p["ln1"], cfg, norm_kind)
    q = dense(h, p["attn"]["wq"], acfg, "attn_proj").reshape(B, H, hd)
    k = dense(h, p["attn"]["wk"], acfg, "attn_proj").reshape(B, KV, hd)
    v = dense(h, p["attn"]["wv"], acfg, "attn_proj").reshape(B, KV, hd)
    posv = jnp.full((B,), pos, jnp.int32)
    q = rope(q[:, None], posv[:, None], cfg.rope_theta)[:, 0]
    k = rope(k[:, None], posv[:, None], cfg.rope_theta)[:, 0]

    write = pos % C  # ring write for sliding-window caches
    ck = jax.lax.dynamic_update_slice(cache["k"], k[:, None].astype(cache["k"].dtype),
                                      (0, write, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v[:, None].astype(cache["v"].dtype),
                                      (0, write, 0, 0))
    attn_out = decode_attention(
        q, ck, cv, slot_positions, pos, cfg.sliding_window, acfg, ctx,
        seq_shard_axis,
    )
    # the residual adds ride the projection epilogues (fused block tail)
    x = dense(attn_out[:, None], p["attn"]["wo"], acfg, "attn_proj",
              residual=x[:, None])[:, 0]

    if "ck" in cache:  # cross attention (enc-dec decode)
        hx = apply_norm(x[:, None], p["lnx"], cfg, norm_kind)
        qx = dense(hx, p["xattn"]["wq"], acfg, "attn_proj").reshape(B, H, hd)
        Tc = cache["ck"].shape[1]
        xo = decode_attention(
            qx, cache["ck"], cache["cv"],
            jnp.broadcast_to(jnp.arange(Tc, dtype=jnp.int32), (B, Tc)),
            jnp.int32(2**30), 0, acfg, ctx, None,
        )
        x = dense(xo[:, None], p["xattn"]["wo"], acfg, "attn_proj",
                  residual=x[:, None])[:, 0]

    h2 = apply_norm(x[:, None], p["ln2"], cfg, norm_kind)
    x = _ffn(h2, p, cfg, ctx, moe_layer, residual=x[:, None])[:, 0]
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ck, cv
    return x, new_cache


def cross_attention_block(*a, **kw):  # pragma: no cover - naming alias
    return block_apply(*a, **kw)
