"""Parameter-tree machinery: one source of truth for shapes, shardings, init.

A model is described as a pytree whose leaves are :class:`P` specs
(shape + logical axis names + init rule).  From that single tree we derive

  * real initialised parameters (smoke tests, examples, training),
  * ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod dry-run),
  * ``jax.sharding.PartitionSpec`` trees (pjit in/out shardings),

so shapes and shardings can never drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

__all__ = ["P", "materialize", "shape_tree", "pspec_tree", "count_params"]


@dataclass(frozen=True)
class P:
    """Leaf spec: shape, logical axes (one name or None per dim), init."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small
    scale: float = 1.0
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x) -> bool:
    return isinstance(x, P)


def materialize(tree, rng: jax.Array, param_dtype: str = "float32"):
    """Initialise real parameters for a spec tree."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_leaf)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, keys):
        dt = jnp.dtype(spec.dtype) if spec.dtype != "float32" else jnp.dtype(param_dtype)
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dt)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dt)
        elif spec.init == "fill":
            arr = jnp.full(spec.shape, spec.scale, dt)
        elif spec.init == "arange":
            arr = jnp.broadcast_to(jnp.arange(spec.shape[-1], dtype=dt), spec.shape)
        else:
            fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
            if spec.init == "small":
                std = 0.02
            else:
                std = spec.scale / np.sqrt(fan_in)
            arr = (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def shape_tree(tree, param_dtype: str = "float32"):
    """ShapeDtypeStruct stand-ins (no allocation) for the dry-run."""

    def one(s: P):
        dt = jnp.dtype(s.dtype) if s.dtype != "float32" else jnp.dtype(param_dtype)
        return jax.ShapeDtypeStruct(s.shape, dt)

    return jax.tree.map(one, tree, is_leaf=_is_leaf)


def pspec_tree(tree, rules: dict):
    """PartitionSpec tree via logical->physical axis rules.

    ``rules`` maps a logical axis name to a mesh axis (or tuple of axes or
    None).  Unknown logical names map to None (replicated).
    """

    def one(spec: P) -> PartitionSpec:
        return PartitionSpec(*(rules.get(a) for a in spec.axes))

    return jax.tree.map(one, tree, is_leaf=_is_leaf)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_leaf)
    return int(sum(np.prod(l.shape) for l in leaves))
