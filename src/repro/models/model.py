"""Unified model API over all assigned architecture families.

``Model(cfg)`` exposes:

  * ``param_specs()`` / ``init(rng)`` / ``pspecs(rules)`` — one source of
    truth for shapes, init and shardings (see models/params.py);
  * ``loss_fn(params, batch, ctx)``   — training objective (causal CE);
  * ``forward(params, batch, ctx)``   — full-sequence logits;
  * ``prefill(params, batch, ctx, cache_len)`` — logits for the last
    position + a filled decode cache;
  * ``decode_step(params, tokens, cache, ctx)`` — one-token serve step;
  * ``cache_specs(batch, cache_len)`` — decode-cache spec tree (dry-run).

Families: dense / moe / vlm (decoder LM), hybrid (Jamba), ssm (xLSTM),
encdec (Whisper backbone).  Frontends (audio frames / vision patches) are
stubs per the assignment: batches carry precomputed embeddings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hybrid as hy
from repro.models import xlstm as xl
from repro.models.layers import ParallelCtx, apply_norm, dense, norm_params
from repro.models.params import P, materialize, pspec_tree, shape_tree
from repro.models.transformer import (
    attn_cache_specs,
    block_apply,
    block_decode,
    block_decode_paged,
    block_params,
    paged_attn_cache_specs,
)

__all__ = ["Model"]

_VIS_DIM = 1024  # stub vision/audio frontend embedding width
_MAXI32 = 2**31 - 1


def _stack(tree, n: int):
    """Add a leading stacked-layer dim to every P leaf."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, (None,) + p.axes, p.init, p.scale, p.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _tree_at(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        D, V = cfg.d_model, cfg.padded_vocab
        specs: dict = {
            "embed": P((V, D), ("vocab", "embed"), "small"),
            "final_norm": norm_params(cfg, cfg.norm),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P((D, V), ("embed", "vocab"))

        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            blk = block_params(cfg, moe_layer=cfg.n_experts > 0,
                               norm_kind=cfg.norm)
            specs["blocks"] = (
                _stack(blk, cfg.n_layers)
                if cfg.scan_layers
                else {f"l{i}": block_params(cfg, cfg.n_experts > 0, cfg.norm)
                      for i in range(cfg.n_layers)}
            )
        elif fam == "hybrid":
            n_super = cfg.n_layers // cfg.attn_every
            sb = hy.superblock_params(cfg)
            specs["blocks"] = _stack(sb, n_super) if cfg.scan_layers else {
                f"l{i}": hy.superblock_params(cfg) for i in range(n_super)
            }
        elif fam == "ssm":
            blocks = {}
            for i in range(cfg.n_layers):
                kind = "slstm" if i in cfg.slstm_at else "mlstm"
                blocks[f"l{i}"] = {
                    "ln": norm_params(cfg, cfg.norm),
                    "kind": kind,  # consumed below, stripped from tree
                }
                blocks[f"l{i}"][kind] = (
                    xl.slstm_params(cfg) if kind == "slstm" else xl.mlstm_params(cfg)
                )
            specs["blocks"] = {
                k: {kk: vv for kk, vv in v.items() if kk != "kind"}
                for k, v in blocks.items()
            }
        elif fam == "encdec":
            specs["adapter"] = P((_VIS_DIM, D), (None, "embed"))
            specs["enc_pos"] = P((cfg.frontend_seq, D), (None, "embed"), "small")
            specs["enc_final_norm"] = norm_params(cfg, cfg.norm)
            eb = block_params(cfg, norm_kind=cfg.norm)
            specs["enc_blocks"] = (
                _stack(eb, cfg.n_encoder_layers)
                if cfg.scan_layers
                else {f"l{i}": block_params(cfg, norm_kind=cfg.norm)
                      for i in range(cfg.n_encoder_layers)}
            )
            db = block_params(cfg, norm_kind=cfg.norm, cross=True)
            specs["blocks"] = (
                _stack(db, cfg.n_layers)
                if cfg.scan_layers
                else {f"l{i}": block_params(cfg, norm_kind=cfg.norm, cross=True)
                      for i in range(cfg.n_layers)}
            )
        else:
            raise ValueError(f"unknown family {fam}")

        if fam == "vlm":
            specs["projector"] = {
                "w1": P((_VIS_DIM, D), (None, "embed")),
                "w2": P((D, D), ("embed", "embed2")),
            }
        return specs

    def init(self, rng):
        return materialize(self.param_specs(), rng, self.cfg.param_dtype)

    def param_shapes(self):
        return shape_tree(self.param_specs(), self.cfg.param_dtype)

    def pspecs(self, rules: dict):
        return pspec_tree(self.param_specs(), rules)

    # ------------------------------------------------------------------
    # embedding / head helpers
    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        emb = params["embed"]
        x = jnp.take(emb, tokens, axis=0).astype(jnp.dtype(self.cfg.dtype))
        return x

    def _logits(self, params, x, ctx: ParallelCtx):
        cfg = self.cfg
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        out = dense(x, head.astype(x.dtype), cfg.approx, "logits")
        return ctx.shard(out.astype(jnp.float32), "batch", None, "vocab")

    def _xlstm_kinds(self):
        return ["slstm" if i in self.cfg.slstm_at else "mlstm"
                for i in range(self.cfg.n_layers)]

    def _encode(self, params, enc_embeds, ctx):
        """Whisper-style encoder over precomputed frontend embeddings."""
        cfg = self.cfg
        x = jnp.einsum("bse,ed->bsd", enc_embeds.astype(jnp.float32),
                       params["adapter"].astype(jnp.float32))
        x = (x + params["enc_pos"].astype(jnp.float32)[None]).astype(
            jnp.dtype(cfg.dtype))
        x = ctx.shard(x, "batch", None, "act_embed")
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(h, lp):
            h, _ = block_apply(h, lp, cfg, ctx, pos, norm_kind=cfg.norm,
                               causal=False)
            return h, None

        x = self._run_stack(params["enc_blocks"], cfg.n_encoder_layers, body, x)
        return apply_norm(x, params["enc_final_norm"], cfg, cfg.norm)

    def _layer_constrainer(self, ctx: ParallelCtx, key: str = "blocks"):
        """Constrain a scanned layer's sliced params to their shardings.

        The backward of a scanned stack accumulates weight gradients into
        stacked buffers; without an in-body anchor GSPMD can leave those
        accumulators fully replicated (9 GiB+ per leaf at Jamba scale).
        Constraining the sliced primal inside the body pins the cotangent
        layout too.
        """
        if ctx.mesh is None or not self.cfg.scan_layers:
            return lambda lp: lp
        from jax.sharding import NamedSharding, PartitionSpec

        stacked = pspec_tree(self.param_specs()[key], ctx.rules)
        layer_ps = jax.tree.map(
            lambda ps: PartitionSpec(*ps[1:]), stacked,
            is_leaf=lambda v: isinstance(v, PartitionSpec))

        def constrain(lp):
            return jax.tree.map(
                lambda a, ps: jax.lax.with_sharding_constraint(
                    a, NamedSharding(ctx.mesh, ps)), lp, layer_ps)

        return constrain

    def _run_stack(self, blocks, n, body, x, remat: Optional[bool] = None):
        """Scan or unrolled loop over a homogeneous stacked block tree."""
        cfg = self.cfg
        f = body
        if remat is None:
            remat = cfg.remat != "none"
        if remat:
            f = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.nothing_saveable
                if cfg.remat == "block"
                else jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        if cfg.scan_layers:
            x, _ = jax.lax.scan(f, x, blocks)
            return x
        for i in range(n):
            x, _ = f(x, blocks[f"l{i}"])
        return x

    # ------------------------------------------------------------------
    # full-sequence forward (training / eval)
    # ------------------------------------------------------------------
    def forward(self, params, batch, ctx: ParallelCtx):
        cfg = self.cfg
        fam = cfg.family
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)

        enc_out = None
        if fam == "vlm":
            pr = params["projector"]
            p = jax.nn.gelu(jnp.einsum(
                "bpe,ed->bpd", batch["patches"].astype(jnp.float32),
                pr["w1"].astype(jnp.float32)))
            p = jnp.einsum("bpd,de->bpe", p, pr["w2"].astype(jnp.float32))
            x = jnp.concatenate([p.astype(x.dtype), x], axis=1)
        elif fam == "encdec":
            enc_out = self._encode(params, batch["enc_embeds"], ctx)

        x = ctx.shard(x, "batch", None, "act_embed")
        T = x.shape[1]
        pos = jnp.arange(T, dtype=jnp.int32)
        enc_pos = (jnp.arange(cfg.frontend_seq, dtype=jnp.int32)
                   if enc_out is not None else None)

        anchor = self._layer_constrainer(ctx)
        if fam in ("dense", "moe", "vlm"):
            def body(h, lp):
                h, _ = block_apply(h, anchor(lp), cfg, ctx, pos,
                                   moe_layer=cfg.n_experts > 0,
                                   norm_kind=cfg.norm)
                return h, None
            x = self._run_stack(params["blocks"], cfg.n_layers, body, x)
        elif fam == "encdec":
            def body(h, lp):
                h, _ = block_apply(h, anchor(lp), cfg, ctx, pos,
                                   norm_kind=cfg.norm,
                                   enc_out=enc_out, enc_positions=enc_pos)
                return h, None
            x = self._run_stack(params["blocks"], cfg.n_layers, body, x)
        elif fam == "hybrid":
            def body(h, lp):
                h, _ = hy.superblock_apply(h, anchor(lp), cfg, ctx, pos)
                return h, None
            x = self._run_stack(params["blocks"],
                                cfg.n_layers // cfg.attn_every, body, x)
        elif fam == "ssm":
            kinds = self._xlstm_kinds()

            def ssm_block(h_in, lp, kind):
                h = apply_norm(h_in, lp["ln"], cfg, cfg.norm)
                if kind == "slstm":
                    h, _ = xl.slstm(h, lp["slstm"], cfg, ctx)
                else:
                    h, _ = xl.mlstm(h, lp["mlstm"], cfg, ctx)
                return h_in + h

            if cfg.remat != "none":
                ssm_block = jax.checkpoint(
                    ssm_block, static_argnums=(2,),
                    policy=jax.checkpoint_policies.nothing_saveable)
            for i, kind in enumerate(kinds):
                x = ssm_block(x, params["blocks"][f"l{i}"], kind)

        x = apply_norm(x, params["final_norm"], cfg, cfg.norm)
        return self._logits(params, x, ctx)

    def loss_fn(self, params, batch, ctx: ParallelCtx):
        """Mean next-token CE over positions with target >= 0."""
        logits = self.forward(params, batch, ctx)
        tgt = batch["targets"]
        # align: logits predict the *next* token at each position
        logits = logits[:, -tgt.shape[1]:]  # drop patch positions (vlm)
        # the logsumexp VJP's softmax divide is grad-of-loss math, not a
        # datapath op the paper's divider replaces
        # audit: exact — logsumexp on the scalar-loss path
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(tgt, 0)[..., None], axis=-1)[..., 0]
        nll = lse - picked
        mask = (tgt >= 0).astype(jnp.float32)
        # one divide per step (+ its VJP), not a datapath op the
        # paper's divider replaces
        # audit: exact — scalar loss mean
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def cache_specs(self, batch: int, cache_n: int, n_pages: int = 0,
                    page_size: int = 0) -> dict:
        """Decode-cache spec tree for a cache of ``cache_n`` slots.

        With ``n_pages``/``page_size`` set, returns the *block-paged*
        cache instead: per-layer KV pools ``[n_pages, page_size, KV,
        hd]`` shared by every slot through a page table (which lives
        host-side in the serve scheduler, not in this tree) — see
        ``repro.serve.paged_kv``.  Paged caches carry no ``pos``/
        ``slots`` entries; per-slot positions are step arguments.
        """
        cfg = self.cfg
        fam = cfg.family
        if n_pages or page_size:
            if fam not in ("dense", "moe", "vlm"):
                raise ValueError(
                    f"paged KV caches need pure-attention decode; family "
                    f"{fam!r} carries recurrent/cross state")
            lc = paged_attn_cache_specs(cfg, n_pages, page_size)
            return {"layers": _stack(lc, cfg.n_layers) if cfg.scan_layers
                    else {f"l{i}": paged_attn_cache_specs(cfg, n_pages,
                                                          page_size)
                          for i in range(cfg.n_layers)}}
        C = min(cache_n, cfg.sliding_window) if cfg.sliding_window else cache_n
        cache_batch_ax = "batch" if batch > 1 else None
        specs: dict = {
            "pos": P((), (), "zeros", dtype="int32"),
            "slots": P((batch, C), (cache_batch_ax, "seq"),
                       "fill", _MAXI32, dtype="int32"),
        }
        if fam in ("dense", "moe", "vlm"):
            lc = attn_cache_specs(cfg, batch, cache_n)
            specs["layers"] = _stack(lc, cfg.n_layers) if cfg.scan_layers else {
                f"l{i}": attn_cache_specs(cfg, batch, cache_n)
                for i in range(cfg.n_layers)
            }
        elif fam == "encdec":
            lc = attn_cache_specs(cfg, batch, cache_n,
                                  cross_len=cfg.frontend_seq)
            specs["layers"] = _stack(lc, cfg.n_layers) if cfg.scan_layers else {
                f"l{i}": attn_cache_specs(cfg, batch, cache_n, cfg.frontend_seq)
                for i in range(cfg.n_layers)
            }
        elif fam == "hybrid":
            sb = hy.superblock_cache_specs(cfg, batch, cache_n)
            n_super = cfg.n_layers // cfg.attn_every
            specs["layers"] = _stack(sb, n_super) if cfg.scan_layers else {
                f"l{i}": hy.superblock_cache_specs(cfg, batch, cache_n)
                for i in range(n_super)
            }
        elif fam == "ssm":
            layers = {}
            for i, kind in enumerate(self._xlstm_kinds()):
                H, hd = cfg.n_heads, cfg.hd
                if kind == "mlstm":
                    from repro.models.xlstm import mlstm_dims
                    _, hd = mlstm_dims(cfg)
                    # head counts are small (4); shard the per-head state
                    # dims on the model axis instead
                    layers[f"l{i}"] = {
                        "C": P((batch, H, hd, hd), (cache_batch_ax, None, None, "ff"), "zeros"),
                        "n": P((batch, H, hd), (cache_batch_ax, None, "ff"), "zeros"),
                        "m": P((batch, H), (cache_batch_ax, None), "fill", -1e30),
                    }
                else:
                    layers[f"l{i}"] = {
                        "c": P((batch, H, hd), (cache_batch_ax, None, "ff"), "zeros"),
                        "n": P((batch, H, hd), (cache_batch_ax, None, "ff"), "zeros"),
                        "m": P((batch, H, hd), (cache_batch_ax, None, "ff"), "fill", -1e30),
                        "h": P((batch, H, hd), (cache_batch_ax, None, "ff"), "zeros"),
                    }
            specs["layers"] = layers
            specs.pop("slots")
        return specs

    def init_cache(self, batch: int, cache_n: int):
        return materialize(self.cache_specs(batch, cache_n),
                           jax.random.PRNGKey(0), "float32")

    def init_paged_cache(self, n_pages: int, page_size: int):
        return materialize(self.cache_specs(0, 0, n_pages, page_size),
                           jax.random.PRNGKey(0), "float32")

    def decode_paged(self, params, tokens, cache, page_table, offsets,
                     n_valid, ctx: ParallelCtx):
        """Paged multi-token step for continuous batching.

        One compiled function serves both engine phases: the decode tick
        (``tokens`` [n_slots, 1], every live slot advances one token at
        its own depth) and a chunked-prefill tick (``tokens`` [1, S],
        one slot absorbs a prompt chunk).  ``offsets`` [B] is each
        slot's stored-KV length before this call, ``n_valid`` [B] how
        many of the S tokens are real (0 = slot inactive; its writes
        are redirected to the scratch page and its logits garbage).

        Returns (logits [B, V] at each row's last valid token, cache).
        """
        cfg = self.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"decode_paged supports attention families; got "
                f"{cfg.family!r}")
        B, S = tokens.shape
        x = self._embed(params, tokens)
        x = ctx.shard(x, "batch", None, "act_embed")
        positions = offsets[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
        valid = jnp.arange(S, dtype=jnp.int32)[None] < n_valid[:, None]
        kv_len = offsets + n_valid

        def body(h, xs):
            lp, lc = xs
            h, nc = block_decode_paged(h, lp, lc, page_table, positions,
                                       valid, kv_len, cfg, ctx,
                                       moe_layer=cfg.n_experts > 0,
                                       norm_kind=cfg.norm)
            return h, nc

        new_cache = dict(cache)
        if cfg.scan_layers:
            x, ncl = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
        else:
            ncl = {}
            for i in range(cfg.n_layers):
                x, ncl[f"l{i}"] = body(x, (params["blocks"][f"l{i}"],
                                           cache["layers"][f"l{i}"]))
        new_cache["layers"] = ncl

        x = apply_norm(x, params["final_norm"], cfg, cfg.norm)
        last = jnp.clip(n_valid - 1, 0, S - 1)
        xl = jnp.take_along_axis(x, last[:, None, None], axis=1)
        logits = self._logits(params, xl, ctx)[:, 0]
        return logits, new_cache

    def decode_step(self, params, tokens, cache, ctx: ParallelCtx,
                    seq_shard_axis: Optional[str] = None):
        """tokens: [B] int32 -> (logits [B, V], new cache)."""
        cfg = self.cfg
        fam = cfg.family
        B = tokens.shape[0]
        pos = cache["pos"]
        x = self._embed(params, tokens[:, None])[:, 0]
        x = ctx.shard(x, "batch", "act_embed")

        new_cache = dict(cache)
        if "slots" in cache:
            C = cache["slots"].shape[1]
            write = pos % C
            slots = jax.lax.dynamic_update_slice(
                cache["slots"], jnp.full((B, 1), pos, jnp.int32), (0, write))
            new_cache["slots"] = slots
        else:
            slots = None

        if fam in ("dense", "moe", "vlm", "encdec"):
            def body(h, xs):
                lp, lc = xs
                h, nc = block_decode(h, lp, lc, slots, pos, cfg, ctx,
                                     moe_layer=cfg.n_experts > 0,
                                     norm_kind=cfg.norm,
                                     seq_shard_axis=seq_shard_axis)
                return h, nc
            if cfg.scan_layers:
                x, ncl = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
            else:
                ncl = {}
                for i in range(cfg.n_layers):
                    x, ncl[f"l{i}"] = body(x, (params["blocks"][f"l{i}"],
                                               cache["layers"][f"l{i}"]))
            new_cache["layers"] = ncl
        elif fam == "hybrid":
            def body(h, xs):
                lp, lc = xs
                h, nc = hy.superblock_decode(h, lp, lc, slots, pos, cfg, ctx,
                                             seq_shard_axis)
                return h, nc
            n_super = cfg.n_layers // cfg.attn_every
            if cfg.scan_layers:
                x, ncl = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
            else:
                ncl = {}
                for i in range(n_super):
                    x, ncl[f"l{i}"] = body(x, (params["blocks"][f"l{i}"],
                                               cache["layers"][f"l{i}"]))
            new_cache["layers"] = ncl
        elif fam == "ssm":
            ncl = {}
            for i, kind in enumerate(self._xlstm_kinds()):
                lp = params["blocks"][f"l{i}"]
                lc = cache["layers"][f"l{i}"]
                h = apply_norm(x[:, None], lp["ln"], cfg, cfg.norm)[:, 0]
                if kind == "slstm":
                    h, st = xl.slstm_decode(h, (lc["c"], lc["n"], lc["m"], lc["h"]),
                                            lp["slstm"], cfg, ctx)
                    ncl[f"l{i}"] = dict(zip(("c", "n", "m", "h"), st))
                else:
                    h, st = xl.mlstm_decode(h, (lc["C"], lc["n"], lc["m"]),
                                            lp["mlstm"], cfg, ctx)
                    ncl[f"l{i}"] = dict(zip(("C", "n", "m"), st))
                x = x + h
            new_cache["layers"] = ncl

        new_cache["pos"] = pos + 1
        x = apply_norm(x[:, None], params["final_norm"], cfg, cfg.norm)
        logits = self._logits(params, x, ctx)[:, 0]
        return logits, new_cache

    def prefill(self, params, batch, ctx: ParallelCtx, cache_n: int):
        """Full-sequence forward that also fills a decode cache.

        Returns (last-position logits [B, V], cache).  Implemented as the
        train-style forward plus cache extraction; attention k/v are
        scattered into (ring) cache buffers.
        """
        cfg = self.cfg
        fam = cfg.family
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        if fam == "vlm":
            pr = params["projector"]
            p = jax.nn.gelu(jnp.einsum(
                "bpe,ed->bpd", batch["patches"].astype(jnp.float32),
                pr["w1"].astype(jnp.float32)))
            p = jnp.einsum("bpd,de->bpe", p, pr["w2"].astype(jnp.float32))
            x = jnp.concatenate([p.astype(x.dtype), x], axis=1)
            S = x.shape[1]
        x = ctx.shard(x, "batch", None, "act_embed")
        pos = jnp.arange(S, dtype=jnp.int32)
        C = min(cache_n, cfg.sliding_window) if cfg.sliding_window else cache_n

        def to_ring(kv):  # [B, S, KV, hd] -> [B, C, KV, hd] (+slot positions)
            if C >= S:
                padded = jnp.pad(kv, ((0, 0), (0, C - S), (0, 0), (0, 0)))
                return padded
            tail = kv[:, S - C:]
            idx = (jnp.arange(C) + (S - C)) % C
            return jnp.zeros((B, C) + kv.shape[2:], kv.dtype).at[:, idx].set(tail)

        def slot_positions():
            if C >= S:
                base = jnp.where(jnp.arange(C) < S, jnp.arange(C), _MAXI32)
            else:
                idx = (jnp.arange(C) + (S - C)) % C
                base = jnp.zeros((C,), jnp.int32).at[idx].set(
                    jnp.arange(S - C, S, dtype=jnp.int32))
            return jnp.broadcast_to(base, (B, C)).astype(jnp.int32)

        enc_out = None
        if fam == "encdec":
            enc_out = self._encode(params, batch["enc_embeds"], ctx)
        enc_pos = (jnp.arange(cfg.frontend_seq, dtype=jnp.int32)
                   if enc_out is not None else None)

        cache: dict = {"pos": jnp.int32(S)}
        if fam != "ssm":
            cache["slots"] = slot_positions()

        if fam in ("dense", "moe", "vlm", "encdec"):
            def body(h, lp):
                h, kv = block_apply(h, lp, cfg, ctx, pos,
                                    moe_layer=cfg.n_experts > 0,
                                    norm_kind=cfg.norm, enc_out=enc_out,
                                    enc_positions=enc_pos, return_kv=True)
                k, v = kv
                lc = {"k": to_ring(k).astype(jnp.dtype(cfg.dtype)),
                      "v": to_ring(v).astype(jnp.dtype(cfg.dtype))}
                if enc_out is not None:
                    acfg = cfg.approx
                    KV, hd = cfg.n_kv_heads, cfg.hd
                    Tc = enc_out.shape[1]
                    lc["ck"] = dense(enc_out, lp["xattn"]["wk"], acfg,
                                     "attn_proj").reshape(B, Tc, KV, hd).astype(
                                         jnp.dtype(cfg.dtype))
                    lc["cv"] = dense(enc_out, lp["xattn"]["wv"], acfg,
                                     "attn_proj").reshape(B, Tc, KV, hd).astype(
                                         jnp.dtype(cfg.dtype))
                return h, lc
            if cfg.scan_layers:
                x, layers = jax.lax.scan(body, x, params["blocks"])
            else:
                layers = {}
                for i in range(cfg.n_layers):
                    x, layers[f"l{i}"] = body(x, params["blocks"][f"l{i}"])
            cache["layers"] = layers
        elif fam == "hybrid":
            def body(h, lp):
                h, lc = hy.superblock_prefill(h, lp, cfg, ctx, pos, to_ring,
                                              jnp.dtype(cfg.dtype))
                return h, lc
            n_super = cfg.n_layers // cfg.attn_every
            if cfg.scan_layers:
                x, layers = jax.lax.scan(body, x, params["blocks"])
            else:
                layers = {}
                for i in range(n_super):
                    x, layers[f"l{i}"] = body(x, params["blocks"][f"l{i}"])
            cache["layers"] = layers
        elif fam == "ssm":
            layers = {}
            for i, kind in enumerate(self._xlstm_kinds()):
                lp = params["blocks"][f"l{i}"]
                h = apply_norm(x, lp["ln"], cfg, cfg.norm)
                if kind == "slstm":
                    h, st = xl.slstm(h, lp["slstm"], cfg, ctx)
                    layers[f"l{i}"] = dict(zip(("c", "n", "m", "h"), st))
                else:
                    h, st = xl.mlstm(h, lp["mlstm"], cfg, ctx)
                    layers[f"l{i}"] = dict(zip(("C", "n", "m"), st))
                x = x + h
            cache["layers"] = layers

        x = apply_norm(x[:, -1:], params["final_norm"], cfg, cfg.norm)
        logits = self._logits(params, x, ctx)[:, 0]
        return logits, cache
