"""Jamba-style hybrid superblock: (attn : mamba = 1 : N-1) with MoE FFNs.

The layer stack is organised as homogeneous *superblocks* of
``cfg.attn_every`` layers (Jamba: 8) so the whole stack can be scanned:
one slot is attention, the rest are Mamba mixers, and FFNs alternate
dense / MoE with period ``cfg.moe_every`` (Jamba: 2).  Each slot owns its
params subtree; the outer dimension (number of superblocks) is stacked
for ``lax.scan``.
"""
from __future__ import annotations


from repro.configs.base import ModelConfig
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.layers import (
    ParallelCtx, apply_norm, attention_params, mlp, mlp_params, norm_params,
)
from repro.models.transformer import block_decode
from repro.models.params import P

__all__ = [
    "superblock_params", "superblock_apply", "superblock_decode",
    "superblock_cache_specs", "attn_slot",
]


def attn_slot(cfg: ModelConfig) -> int:
    return cfg.attn_every // 2


def _slot_is_moe(cfg: ModelConfig, s: int) -> bool:
    return cfg.n_experts > 0 and (s % cfg.moe_every == cfg.moe_every - 1)


def superblock_params(cfg: ModelConfig) -> dict:
    p = {}
    for s in range(cfg.attn_every):
        slot = {"ln1": norm_params(cfg, cfg.norm)}
        if s == attn_slot(cfg):
            slot["attn"] = attention_params(cfg)
        else:
            slot["mamba"] = mb.mamba_params(cfg)
        slot["ln2"] = norm_params(cfg, cfg.norm)
        slot["ffn"] = (
            moe_mod.moe_params(cfg) if _slot_is_moe(cfg, s) else mlp_params(cfg)
        )
        p[f"slot{s}"] = slot
    return p


def superblock_apply(x, p, cfg: ModelConfig, ctx: ParallelCtx, positions,
                     return_kv: bool = False):
    """Full-sequence superblock. Returns (x, kv_of_attn_slot_or_None)."""
    from repro.models.layers import attention

    import jax

    kv = None
    x = ctx.shard(x, "batch", "seq_act", None)

    def slot_apply(xin, slot, s):
        h = apply_norm(xin, slot["ln1"], cfg, cfg.norm)
        k = v = None
        if s == attn_slot(cfg):
            h, k, v = attention(h, slot["attn"], cfg, ctx, positions)
        else:
            h, _ = mb.mamba(h, slot["mamba"], cfg, ctx)
        xin = xin + h
        h2 = apply_norm(xin, slot["ln2"], cfg, cfg.norm)
        if _slot_is_moe(cfg, s):
            xin = xin + moe_mod.moe_ffn(h2, slot["ffn"], cfg, ctx)
        else:
            xin = xin + mlp(h2, slot["ffn"], cfg, ctx)
        return xin, k, v

    if cfg.remat != "none":
        # nested remat: the outer scan checkpoints the superblock; the
        # per-slot checkpoint bounds the recompute liveset to ONE slot's
        # intermediates instead of all attn_every slots at once
        slot_apply = jax.checkpoint(
            slot_apply, static_argnums=(2,),
            policy=jax.checkpoint_policies.nothing_saveable)
    for s in range(cfg.attn_every):
        x, k, v = slot_apply(x, p[f"slot{s}"], s)
        if return_kv and s == attn_slot(cfg):
            kv = (k, v)
    return x, kv


def superblock_cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Per-superblock decode cache: attn {k,v} + per-mamba-slot states."""
    from repro.models.transformer import attn_cache_specs

    d_inner = cfg.ssm_expand * cfg.d_model
    N, K = cfg.ssm_state, cfg.ssm_conv
    cache_batch_ax = "batch" if batch > 1 else None
    specs = {}
    for s in range(cfg.attn_every):
        if s == attn_slot(cfg):
            specs[f"slot{s}"] = attn_cache_specs(cfg, batch, seq_len)
        else:
            specs[f"slot{s}"] = {
                "h": P((batch, d_inner, N), (cache_batch_ax, "ff", None), "zeros"),
                "conv": P((batch, K - 1, d_inner), (cache_batch_ax, None, "ff"),
                          "zeros", dtype=cfg.dtype),
            }
    return specs


def superblock_prefill(x, p, cfg: ModelConfig, ctx: ParallelCtx, positions,
                       to_ring, cache_dtype):
    """Full-sequence pass that also returns the superblock's decode cache."""
    from repro.models.layers import attention

    cache = {}
    for s in range(cfg.attn_every):
        slot = p[f"slot{s}"]
        h = apply_norm(x, slot["ln1"], cfg, cfg.norm)
        if s == attn_slot(cfg):
            h, k, v = attention(h, slot["attn"], cfg, ctx, positions)
            cache[f"slot{s}"] = {"k": to_ring(k).astype(cache_dtype),
                                 "v": to_ring(v).astype(cache_dtype)}
        else:
            h, st = mb.mamba(h, slot["mamba"], cfg, ctx)
            cache[f"slot{s}"] = {"h": st[0], "conv": st[1].astype(cache_dtype)}
        x = x + h
        h2 = apply_norm(x, slot["ln2"], cfg, cfg.norm)
        if _slot_is_moe(cfg, s):
            x = x + moe_mod.moe_ffn(h2, slot["ffn"], cfg, ctx)
        else:
            x = x + mlp(h2, slot["ffn"], cfg, ctx)
    return x, cache


def superblock_decode(x, p, cache, slot_positions, pos, cfg: ModelConfig,
                      ctx: ParallelCtx, seq_shard_axis=None):
    """One-token decode through a superblock. x: [B, D]."""
    new_cache = {}
    for s in range(cfg.attn_every):
        slot = p[f"slot{s}"]
        sc = cache[f"slot{s}"]
        if s == attn_slot(cfg):
            x, nc = block_decode(
                x, slot, sc, slot_positions, pos, cfg, ctx,
                moe_layer=_slot_is_moe(cfg, s), norm_kind=cfg.norm,
                seq_shard_axis=seq_shard_axis,
            )
            new_cache[f"slot{s}"] = nc
        else:
            h = apply_norm(x[:, None], slot["ln1"], cfg, cfg.norm)[:, 0]
            h, st = mb.mamba_decode(h, (sc["h"], sc["conv"]), slot["mamba"],
                                    cfg, ctx)
            x = x + h
            h2 = apply_norm(x[:, None], slot["ln2"], cfg, cfg.norm)
            if _slot_is_moe(cfg, s):
                x = x + moe_mod.moe_ffn(h2, slot["ffn"], cfg, ctx)[:, 0]
            else:
                x = x + mlp(h2, slot["ffn"], cfg, ctx)[:, 0]
            new_cache[f"slot{s}"] = {"h": st[0], "conv": st[1]}
    return x, new_cache
