"""Core NN layers: norms, RoPE, GQA/SWA attention, MLPs — RAPID-aware.

Every weight matmul routes through :func:`repro.core.ops.qmatmul`, so any
layer can run with the exact MXU path or the paper's logarithmic
multiplier; every softmax / normalisation divide can route through the
logarithmic divider.  Layers never touch the mesh directly — they get a
:class:`ParallelCtx` whose ``shard`` is a no-op on a single device.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ApproxConfig, ModelConfig
from repro.core.backend import SOFTMAX_FLOOR, Epilogue
from repro.core.ops import (
    exact_einsum,
    qdecode_attn,
    qdiv,
    qmatmul,
    qrms_div,
    qsoftmax_div,
)
from repro.kernels.flash_attn.ref import decode_stats
from repro.models.params import P

__all__ = [
    "ParallelCtx",
    "dense",
    "rms_norm",
    "layer_norm",
    "rope",
    "attention_params",
    "attention",
    "decode_attention",
    "chunk_cache_attention",
    "mlp_params",
    "mlp",
    "norm_params",
    "apply_norm",
]

# Logical -> physical axis rules (see parallel/sharding.py for the menu).
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "embed": None,
    "ff": "model",
    "heads": "model",
    "kv": None,
    "vocab": "model",
    "expert": "model",
    "fsdp": "data",
    "seq": None,
    "seq_act": None,
    "act_embed": None,
}


@dataclass
class ParallelCtx:
    """Mesh handle + axis rules; absent mesh means pure local execution."""

    mesh: Optional[object] = None  # jax.sharding.Mesh
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    def axes(self, *logical):
        """Logical names -> PartitionSpec; unknown names raise.

        Silently mapping an unknown logical axis to None used to make
        sharding-constraint typos vanish (the constraint became a no-op
        replication); every name must now exist in the rule table
        (``None``/"" entries are still the explicit way to replicate).
        """
        phys = []
        for a in logical:
            if not a:
                phys.append(None)
                continue
            if a not in self.rules:
                raise KeyError(
                    f"unknown logical axis {a!r}; rule table has "
                    f"{sorted(self.rules)} — add it to the ctx rules / "
                    "layers.DEFAULT_RULES / parallel.sharding.make_rules")
            phys.append(self.rules[a])
        return PartitionSpec(*phys)

    def shard(self, x, *logical):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.axes(*logical))
        )

    @property
    def data_axes(self):
        """Mesh axes carrying the batch dimension."""
        ax = self.rules.get("batch")
        if ax is None:
            return ()
        return ax if isinstance(ax, tuple) else (ax,)


# --------------------------------------------------------------------------
# dense / norms / rope
# --------------------------------------------------------------------------

def dense(x, w, acfg: ApproxConfig, site: str, bias=None, activation=None,
          residual=None, epilogue=None):
    """x @ w with optional RAPID multiplier at this site.

    ``bias``/``activation``/``residual``/``epilogue`` ride the fused
    matmul epilogue menu (exact and approximate backends alike); the
    backend comes from the registry via the *per-site* override
    ``acfg.backend_for(site)`` ("auto" defers to env/default/hardware —
    see repro.core.backend).
    """
    return qmatmul(x, w, acfg.mul(site), backend=acfg.backend_for(site),
                   bias=bias, activation=activation, residual=residual,
                   epilogue=epilogue)


def norm_params(cfg: ModelConfig, kind: str = "rms") -> dict:
    p = {"scale": P((cfg.d_model,), ("embed",), "ones")}
    if kind == "ln":
        p["bias"] = P((cfg.d_model,), ("embed",), "zeros")
    return p


def rms_norm(x, params, eps: float, acfg: ApproxConfig):
    # qrms_div owns both paths: exact, or mean-of-squares + sqrt + RAPID
    # divide fused in one registry op (one kernel launch on the pallas
    # backend, engine-pinnable)
    xf = x.astype(jnp.float32)
    y = qrms_div(xf, eps, acfg.div("norm"), backend=acfg.backend_for("norm"))
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, params, eps: float, acfg: ApproxConfig):
    # layer norm == rms normalize of the centred activations
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    y = qrms_div(xf - mu, eps, acfg.div("norm"),
                 backend=acfg.backend_for("norm"))
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(x, params, cfg: ModelConfig, kind: str = "rms"):
    if kind == "ln":
        return layer_norm(x, params, cfg.norm_eps, cfg.approx)
    return rms_norm(x, params, cfg.norm_eps, cfg.approx)


def rope(x, positions, theta: float):
    """Rotary embedding, llama-style half rotation. x: [..., S, H, hd]."""
    hd = x.shape[-1]
    half = hd // 2
    # audit: exact — rotary frequency table (position math, not a datapath divide)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def attention_params(cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": P((D, H * hd), ("embed", "heads")),
        "wk": P((D, KV * hd), ("embed", "kv")),
        "wv": P((D, KV * hd), ("embed", "kv")),
        "wo": P((H * hd, D), ("heads", "embed"), scale=1.0),
    }


def _online_softmax_combine(acc, l, m, acfg: ApproxConfig):
    # the denominator comes from the online scan, so this is the
    # registry's *elementwise* div family (broadcast over the head dim);
    # same floor as the fused softmax_div path so the two softmax
    # formulations keep agreeing on fully-masked rows
    sch = acfg.div("softmax")
    l = jnp.maximum(l, SOFTMAX_FLOOR)
    if sch:
        return qdiv(acc, l[..., None], sch,
                    backend=acfg.backend_for("softmax"))
    return acc / l[..., None]  # audit: exact — the exact-softmax arm (sch is None)


def _attn_blockwise(q, k, v, q_pos, kv_pos, window: int, causal: bool,
                    acfg: ApproxConfig, chunk: int = 512):
    """Memory-efficient attention with online softmax.

    q: [B, S, KV, G, hd]; k, v: [B, T, KV, hd].  Masking from absolute
    positions (supports causal + sliding window + cross attention).
    Scans over KV chunks; peak memory O(S * chunk) per head group.
    """
    B, S, KVh, G, hd = q.shape
    T = k.shape[1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    steps = (T + pad) // chunk
    ks = k.reshape(B, steps, chunk, KVh, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, steps, chunk, KVh, hd).transpose(1, 0, 2, 3, 4)
    kvp = kv_pos.reshape(steps, chunk)

    # audit: exact — trace-constant 1/sqrt(hd) (folds at trace time)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = q.astype(jnp.float32) * scale

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs
        s = exact_einsum("bskgh,bckh->bskgc", qf, kc.astype(jnp.float32))
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= pc[None, :] <= q_pos[:, None]
        if window:
            mask &= pc[None, :] > (q_pos[:, None] - window)
        mask &= (pc < jnp.iinfo(jnp.int32).max)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isfinite(m_new)[..., None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        l = l * corr + p.sum(axis=-1)
        pv = exact_einsum("bskgc,bckh->bskgh", p, vc.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, S, KVh, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, KVh, G), jnp.float32)
    a0 = jnp.zeros((B, S, KVh, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kvp))
    out = _online_softmax_combine(acc, l, m, acfg)
    return out.astype(q.dtype)


def _attn_qchunk_core(qc, k, v, qp, kv_pos, window: int, causal: bool,
                      acfg: ApproxConfig):
    """Scores+softmax+PV for one (pre-scaled) q chunk against full K/V."""
    s = exact_einsum("bshd,bthd->bhst", qc.astype(jnp.float32),
                     k.astype(jnp.float32))
    mask = jnp.ones((qc.shape[1], k.shape[1]), bool)
    if causal:
        mask &= kv_pos[None, :] <= qp[:, None]
    if window:
        mask &= kv_pos[None, :] > (qp[:, None] - window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    sch = acfg.div("softmax")
    if sch:
        m = jax.lax.stop_gradient(s.max(axis=-1, keepdims=True))
        e = jnp.exp(s - m)
        # fused softmax combine: row-sum + floor + RAPID divide in one
        # registry op (single VMEM pass on the pallas backend)
        p = qsoftmax_div(e, sch, backend=acfg.backend_for("softmax"))
    else:
        p = jax.nn.softmax(s, axis=-1)
    return exact_einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))


_Q_CHUNK = 1024


def _attn_plain(q, k, v, q_pos, kv_pos, window: int, causal: bool,
                acfg: ApproxConfig):
    """Masked attention, scanned over q chunks with per-chunk remat.

    q: [B,S,H,hd]; k,v: [B,T,H,hd] (heads already repeated to H and
    sharded on the model axis).  The [B,H,chunk,T] score tensor is the
    only quadratic-memory object; rematting each q chunk keeps backward
    memory at O(chunk x T) per layer instead of several live O(S x T)
    tensors (flash-attention-style, without a custom bwd)."""
    B, S, H, hd = q.shape
    # audit: exact — trace-constant 1/sqrt(hd) (folds at trace time)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qs = q.astype(jnp.float32) * scale
    if S <= _Q_CHUNK:
        out = _attn_qchunk_core(qs, k, v, q_pos, kv_pos, window, causal, acfg)
        return out.astype(q.dtype)

    C = _Q_CHUNK
    pad = (-S) % C
    if pad:
        qs = jnp.pad(qs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=0)
    steps = (S + pad) // C
    qcs = qs.reshape(B, steps, C, H, hd).transpose(1, 0, 2, 3, 4)
    qps = q_pos.reshape(steps, C)

    core = jax.checkpoint(
        lambda qc, qp: _attn_qchunk_core(qc, k, v, qp, kv_pos, window,
                                         causal, acfg),
        policy=jax.checkpoint_policies.nothing_saveable)

    def step(_, xs):
        qc, qp = xs
        return None, core(qc, qp)

    _, outs = jax.lax.scan(step, None, (qcs, qps))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, steps * C, H, hd)[:, :S]
    return out.astype(q.dtype)


# sequences longer than this use the O(S*chunk) blockwise path (prefill);
# training shapes (<= 8k) use the one-shot path under layer remat.
_PLAIN_ATTN_MAX_T = 8192


def attention(x, params, cfg: ModelConfig, ctx: ParallelCtx, positions,
              kv_x=None, kv_positions=None, causal: bool = True,
              chunk: int = 1024, residual=None, tail_norm: bool = False):
    """Full-sequence (train / prefill) GQA attention.

    Returns (out [B,S,D], k [B,T,KV,hd], v) — callers keep k/v for caches.
    ``kv_x`` switches to cross-attention (whisper decoder).

    Fused block tail: ``residual`` rides the output projection's matmul
    epilogue (``wo @ .. + residual`` in one pass), and ``tail_norm=True``
    additionally fuses the *following* rms normalization's division into
    the same pass (`norm(out_proj + residual)` on the VMEM-resident
    output tile, RAPID divider included) — ``out`` then becomes the pair
    ``(y, y_rms_div)`` where ``y`` is the residual stream and
    ``y_rms_div`` the scale-free normalized value the next sublayer's
    ``scale`` multiplies.
    """
    acfg = cfg.approx
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_x is None else kv_x
    T = src.shape[1]
    kv_positions = positions if kv_positions is None else kv_positions

    q = dense(x, params["wq"], acfg, "attn_proj").reshape(B, S, H, hd)
    k = dense(src, params["wk"], acfg, "attn_proj").reshape(B, T, KV, hd)
    v = dense(src, params["wv"], acfg, "attn_proj").reshape(B, T, KV, hd)
    if kv_x is None:  # self attention -> rotary
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    q = ctx.shard(q, "batch", None, "heads", None)
    k = ctx.shard(k, "batch", None, "kv", None)
    v = ctx.shard(v, "batch", None, "kv", None)

    G = H // KV
    window = cfg.sliding_window if kv_x is None else 0
    is_causal = causal and kv_x is None
    if T <= _PLAIN_ATTN_MAX_T:
        # repeat kv heads to H so the head axis shards cleanly on "model"
        kr = ctx.shard(jnp.repeat(k, G, axis=2), "batch", None, "heads", None)
        vr = ctx.shard(jnp.repeat(v, G, axis=2), "batch", None, "heads", None)
        out = _attn_plain(q, kr, vr, positions, kv_positions, window,
                          is_causal, acfg)
    else:
        qg = q.reshape(B, S, KV, G, hd)
        out = _attn_blockwise(qg, k, v, positions, kv_positions, window,
                              is_causal, acfg, chunk)
    out = out.reshape(B, S, H * hd)
    if tail_norm:
        ep = Epilogue(norm="rms", div_scheme=acfg.div("norm"),
                      eps=cfg.norm_eps, keep_prenorm=True)
        ydiv, y = dense(out, params["wo"], acfg, "attn_proj",
                        residual=residual, epilogue=ep)
        return (ctx.shard(y, "batch", "seq_act", "act_embed"),
                ctx.shard(ydiv, "batch", "seq_act", "act_embed")), k, v
    out = dense(out, params["wo"], acfg, "attn_proj", residual=residual)
    return ctx.shard(out, "batch", "seq_act", "act_embed"), k, v


def decode_attention(q, k_cache, v_cache, slot_positions, pos, window: int,
                     acfg: ApproxConfig, ctx: Optional[ParallelCtx] = None,
                     seq_shard_axis: Optional[str] = None):
    """Single-token attention against a (possibly ring) KV cache.

    q: [B, H, hd]; caches: [B, C, KV, hd]; slot_positions: [B, C] absolute
    positions stored in each cache slot (MAX_INT = empty).  ``pos`` is the
    current absolute position — a scalar (lockstep batch) or an int32
    ``[B]`` vector (continuous batching: every slot decodes at its own
    depth).  When ``seq_shard_axis`` is given the cache length axis is
    sharded over that mesh axis and partial softmax stats are combined
    with collectives (flash-decode) — used by the 500k-context cells.

    The unsharded path routes the registry's ``decode_attn`` family: on
    the pallas backends the score matmul, online softmax stats, value
    matmul and the RAPID combine divide fuse into one flash kernel (no
    separate matmul + combine passes); the jnp backend is the exact-
    stats reference with identical combine semantics.  The softmax site
    selects the backend, as it owns the only approximate op here.
    """
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    # audit: exact — trace-constant 1/sqrt(hd) (folds at trace time)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, G, hd)
    # [B] per-slot positions broadcast against [B, C] slot maps; the
    # flash-decode shard_map path keeps the scalar-only contract
    posq = jnp.asarray(pos)
    if posq.ndim == 1:
        if seq_shard_axis is not None:
            raise NotImplementedError(
                "per-slot pos vectors are not supported on the "
                "sequence-sharded flash-decode path")
        posq = posq[:, None]

    if seq_shard_axis is None:
        out = qdecode_attn(qf, k_cache, v_cache, slot_positions, posq,
                           window, acfg.div("softmax"),
                           backend=acfg.backend_for("softmax"))
    else:
        from repro.compat import shard_map
        from repro.core import backend as be

        mesh = ctx.mesh
        batch_ax = ctx.rules.get("batch") if q.shape[0] > 1 else None
        spec_q = PartitionSpec(batch_ax, None, None, None)
        spec_c = PartitionSpec(batch_ax, seq_shard_axis, None, None)
        spec_p = PartitionSpec(batch_ax, seq_shard_axis)

        # the softmax combine runs *inside* the manual region: after the
        # psums every device holds the full stats, so dividing per shard
        # is replicated work, but it lets the fused div kernel serve the
        # combine (device-local pallas is legal here; resolve it as such)
        acfg_local = acfg
        if acfg.div("softmax"):
            acfg_local = be.resolve_site_device_local(acfg, "softmax")

        def shmap_body(qc, kc, vc, sp):
            m, l, acc = decode_stats(qc, kc, vc, sp, posq, window)
            m_g = jax.lax.pmax(m, seq_shard_axis)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g), 0.0)
            l_g = jax.lax.psum(l * corr, seq_shard_axis)
            acc_g = jax.lax.psum(acc * corr[..., None], seq_shard_axis)
            return _online_softmax_combine(acc_g, l_g, m_g, acfg_local)

        out = shard_map(
            shmap_body, mesh=mesh,
            in_specs=(spec_q, spec_c, spec_c, spec_p),
            out_specs=PartitionSpec(batch_ax, None, None, None),
            check_vma=False,
        )(qf, k_cache, v_cache, slot_positions)

    return out.reshape(B, H * hd).astype(q.dtype)


def chunk_cache_attention(q, k_cache, v_cache, q_pos, kv_pos, window: int,
                          acfg: ApproxConfig):
    """Multi-token chunk attention against a per-slot cache view.

    The chunked-prefill analogue of :func:`decode_attention`: ``S`` new
    query tokens of each slot attend to that slot's cached prefix (which
    already includes the chunk itself — callers write k/v before
    reading).  q: [B, S, H, hd]; caches: [B, C, KV, hd]; q_pos: [B, S]
    absolute query positions; kv_pos: [B, C] absolute positions stored
    per cache slot (MAX_INT = empty, which causality masks out).  Same
    max-subtracted formulation and registry softmax combine as the
    decode path, so the two agree on fully-masked rows.
    """
    B, S, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    # audit: exact — trace-constant 1/sqrt(hd) (folds at trace time)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, S, KV, G, hd)
    s = exact_einsum("bskgh,bckh->bskgc", qf, k_cache.astype(jnp.float32))
    mask = kv_pos[:, None, :] <= q_pos[:, :, None]  # [B, S, C]
    if window:
        mask &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1)
    p = jnp.where(jnp.isfinite(m)[..., None], jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = exact_einsum("bskgc,bckh->bskgh", p, v_cache.astype(jnp.float32))
    out = _online_softmax_combine(acc, l, m, acfg)
    return out.reshape(B, S, H * hd).astype(q.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def mlp_params(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "silu":
        return {
            "w1": P((D, F), ("embed", "ff")),
            "w3": P((D, F), ("embed", "ff")),
            "w2": P((F, D), ("ff", "embed")),
        }
    return {
        "w1": P((D, F), ("embed", "ff")),
        "w2": P((F, D), ("ff", "embed")),
    }


def mlp(x, params, cfg: ModelConfig, ctx: ParallelCtx, residual=None):
    # the gate/up activation rides the matmul's fused epilogue (on the
    # pallas backend it is applied to the VMEM-resident output tile);
    # ``residual`` fuses the block's residual add into the down-
    # projection's epilogue the same way — no extra HBM round-trip
    acfg = cfg.approx
    h = dense(x, params["w1"], acfg, "mlp", activation=cfg.act)
    h = ctx.shard(h, "batch", None, "ff")
    if cfg.act == "silu":
        h = h * dense(x, params["w3"], acfg, "mlp")
    out = dense(h, params["w2"], acfg, "mlp", residual=residual)
    return ctx.shard(out, "batch", "seq_act", "act_embed")
