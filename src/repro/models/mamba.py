"""Mamba (selective SSM) block — the recurrent half of the Jamba hybrid.

Training/prefill use a chunked associative scan (TPU-friendly: intra-chunk
work is dense VPU/MXU math on [B, chunk, d_inner, N] tiles, inter-chunk
state is carried by a short ``lax.scan``).  Decode is the O(1) recurrent
update.  ``d_inner`` is sharded on the model axis ("ff" logical axis) —
the SSM state never crosses devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParallelCtx, dense
from repro.models.params import P

__all__ = ["mamba_params", "mamba", "mamba_decode", "mamba_init_cache"]

_CHUNK = 32


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_inner, dt_rank


def mamba_params(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_inner, dt_rank = _dims(cfg)
    N, K = cfg.ssm_state, cfg.ssm_conv
    return {
        "in_proj": P((D, 2 * d_inner), ("embed", "ff")),
        "conv_w": P((K, d_inner), (None, "ff"), "normal", 0.5),
        "conv_b": P((d_inner,), ("ff",), "zeros"),
        "x_proj": P((d_inner, dt_rank + 2 * N), ("ff", None)),
        "dt_proj": P((dt_rank, d_inner), (None, "ff"), "small"),
        "dt_bias": P((d_inner,), ("ff",), "ones"),
        "a_log": P((d_inner, N), ("ff", None), "zeros"),
        "d_skip": P((d_inner,), ("ff",), "ones"),
        "out_proj": P((d_inner, D), ("ff", "embed")),
    }


def _ssm_inputs(x_in, params, cfg: ModelConfig):
    """Common pre-scan computation. x_in: [..., d_inner] (post conv+silu)."""
    _, dt_rank = _dims(cfg)
    N = cfg.ssm_state
    xdbc = jnp.einsum("...i,ij->...j", x_in.astype(jnp.float32),
                      params["x_proj"].astype(jnp.float32))
    dt_r, Bm, Cm = jnp.split(xdbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt_r, params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"].astype(jnp.float32)
    )  # [..., d_inner]
    A = -jnp.exp(params["a_log"].astype(jnp.float32))  # [d_inner, N]
    return dt, A, Bm, Cm


def _conv_causal(x, w, b, state=None):
    """Depthwise causal conv along S. x: [B,S,C]; w: [K,C]; state: [B,K-1,C]."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :], xp[:, -(K - 1) :, :]


def mamba(x, params, cfg: ModelConfig, ctx: ParallelCtx):
    """Train/prefill forward. x: [B, S, D] -> ([B, S, D], final_state)."""
    B, S, D = x.shape
    d_inner, _ = _dims(cfg)
    N = cfg.ssm_state
    acfg = cfg.approx

    xz = dense(x, params["in_proj"], acfg, "mlp")
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = ctx.shard(x_in, "batch", None, "ff")
    x_in, conv_state = _conv_causal(
        x_in, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype)
    )
    x_in = jax.nn.silu(x_in)

    dt, A, Bm, Cm = _ssm_inputs(x_in, params, cfg)
    xf = x_in.astype(jnp.float32)

    # chunked associative scan over S.  Discretisation (exp(dt*A), dt*B*x)
    # happens INSIDE the chunk step: materialising it for the full
    # sequence would cost O(S*d_inner*N) f32 per layer (hundreds of GiB at
    # Jamba scale) and, saved under the remat scan, dominated device
    # memory; per-chunk it is O(chunk*d_inner*N) and recomputed in bwd.
    C_ = min(_CHUNK, S)
    pad = (-S) % C_
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
    steps = (S + pad) // C_

    def chunk(t):  # [B,S',...] -> [steps, B, C_, ...]
        return t.reshape(B, steps, C_, -1).transpose(1, 0, 2, 3)

    dts, Bs, Cs, xs = chunk(dt), chunk(Bm), chunk(Cm), chunk(xf)

    def chunk_step(h, inp):
        dtc, bc, cc, xc = inp  # [B,C,di], [B,C,N], [B,C,N], [B,C,di]
        da = jnp.exp(dtc[..., None] * A[None, None])        # [B,C,di,N]
        dbx = (dtc * xc)[..., None] * bc[:, :, None, :]

        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        aa, bb = jax.lax.associative_scan(op, (da, dbx), axis=1)
        hs = aa * h[:, None] + bb                           # [B,C,di,N]
        y = jnp.einsum("bcin,bcn->bci", hs, cc)
        return hs[:, -1], y

    h0 = jnp.zeros((B, d_inner, N), jnp.float32)
    # remat the chunk body: the inner scan otherwise stacks the full
    # [steps, B, C, d_inner, N] state history for its backward pass
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                              (dts, Bs, Cs, xs))
    y = ys.transpose(1, 0, 2, 3).reshape(B, steps * C_, d_inner)[:, :S]

    y = y + params["d_skip"].astype(jnp.float32) * xf[:, :S]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, params["out_proj"], acfg, "mlp")
    return ctx.shard(out, "batch", "seq_act", "act_embed"), (h_last, conv_state)


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, _ = _dims(cfg)
    return (
        jnp.zeros((batch, d_inner, cfg.ssm_state), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype),
    )


def mamba_decode(x, cache, params, cfg: ModelConfig, ctx: ParallelCtx):
    """Single-token step. x: [B, D]; cache: (h [B,di,N], conv [B,K-1,di])."""
    B, D = x.shape
    acfg = cfg.approx
    h, conv_state = cache

    xz = dense(x[:, None, :], params["in_proj"], acfg, "mlp")
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in, conv_state = _conv_causal(
        x_in, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype),
        state=conv_state,
    )
    x_in = jax.nn.silu(x_in)[:, 0]  # [B, di]
    z = z[:, 0]

    dt, A, Bm, Cm = _ssm_inputs(x_in, params, cfg)
    xf = x_in.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A[None])                   # [B,di,N]
    h = dA * h + (dt * xf)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bin,bn->bi", h, Cm)
    y = y + params["d_skip"].astype(jnp.float32) * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(y[:, None, :], params["out_proj"], acfg, "mlp")[:, 0]
    return out, (h, conv_state)
