"""Mixture-of-Experts FFN with sort-based capacity routing and EP-over-TP.

Expert parallelism maps onto the "model" mesh axis: every device holds
E/model_size experts and processes *all of its local tokens* against its
local expert slice; the layer output is the psum over the model axis —
the same collective a dense TP FFN needs, so EP adds **zero** extra
communication volume versus dense TP (no all-to-all).  Routing/dispatch
is done locally per device with a static-shape sort + capacity buffer
(dropless up to the capacity factor).

The layer runs in two modes sharing the same routing core:
  * ``mesh=None``  — pure local execution (smoke tests, CPU examples);
  * ``shard_map``  — the production EP path used by the dry-run.

Backend routing: the per-expert contractions inside the shard_map bodies
are *device-local* (they see per-shard shapes), so the "mlp" site's
backend is resolved once with ``device_local=True`` before the bodies
are built — on a multi-device TPU that turns the hardware-autodetect
(``AUTO_HW``) pin into the pallas kernels on local shards, where the
old code silently fell back to the jnp formulation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.compat import shard_map

from repro.configs.base import ApproxConfig, ModelConfig
from repro.core import backend as be
from repro.core.ops import approx_softmax, exact_einsum, qmatmul_batched
from repro.models.layers import ParallelCtx, mlp, mlp_params
from repro.models.params import P

__all__ = ["moe_params", "moe_ffn"]


def _router_gates(gval: jnp.ndarray,
                  acfg: Optional[ApproxConfig]) -> jnp.ndarray:
    """Top-k gate normalisation through the registry softmax path.

    The same site semantics as attention: an approx config carrying a
    softmax divider routes the gates through the ``softmax_div`` family
    (the last allowlisted router escape in the jaxpr audit); the exact
    arm of ``approx_softmax`` is bit-identical to ``jax.nn.softmax``.
    """
    sch = acfg.div("softmax") if acfg is not None else None
    bk = acfg.backend_for("softmax") if acfg is not None else None
    return approx_softmax(gval, axis=-1, div_scheme=sch, backend=bk)


def moe_params(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    # Expert dim shards on "model" (EP==TP); the per-expert ff dim shards
    # on "data" (FSDP at rest, all-gathered just-in-time inside the layer).
    D, E, F = cfg.d_model, cfg.n_experts, d_ff or cfg.d_ff
    p = {
        "router": P((D, E), ("embed", None), "small"),
        "w1": P((E, D, F), ("expert", None, "expert_ff")),
        "w3": P((E, D, F), ("expert", None, "expert_ff")),
        "w2": P((E, F, D), ("expert", "expert_ff", None)),
    }
    if cfg.shared_expert:
        p["shared"] = mlp_params(cfg, F)
    return p


def _manual_acfg(acfg: Optional[ApproxConfig]) -> Optional[ApproxConfig]:
    """Resolve the expert-compute ("mlp") backend for a shard_map body.

    The body's call sites are device-local (per-shard shapes), so the
    hardware level may legally pick the per-device pallas kernels even
    on a multi-device process.  Resolving once here (device_local=True)
    pins the body's kernel choice before tracing begins instead of
    relying on in-trace axis-env detection at every dispatch; explicit
    per-site names pass through untouched.
    """
    if acfg is None or not acfg.mul("mlp"):
        return acfg
    return be.resolve_site_device_local(acfg, "mlp")


def _expert_compute(buf, w1, w3, w2, acfg: Optional[ApproxConfig] = None):
    """buf: [E_loc, C, D] -> SwiGLU through per-expert weights.

    Inputs stay in their (bf16) storage dtype; the MXU accumulates f32
    (preferred_element_type) — halves the routing buffers' footprint.
    When the mul scheme is active at the "mlp" site, the per-expert
    contractions route through the backend registry's vmapped batched
    qmatmul (with the silu gate fused into the w1 epilogue) instead of
    the exact einsum.
    """
    sch = acfg.mul("mlp") if acfg is not None else None
    if sch:
        bk = acfg.backend_for("mlp")
        g1 = qmatmul_batched(buf, w1.astype(buf.dtype), sch, backend=bk,
                             activation="silu")
        h3 = qmatmul_batched(buf, w3.astype(buf.dtype), sch, backend=bk)
        act = (g1.astype(jnp.float32) * h3.astype(jnp.float32)).astype(buf.dtype)
        return qmatmul_batched(act, w2.astype(buf.dtype), sch,
                               backend=bk).astype(jnp.float32)
    f32 = jnp.float32
    h1 = jnp.einsum("ecd,edf->ecf", buf, w1.astype(buf.dtype),
                    preferred_element_type=f32)
    h3 = jnp.einsum("ecd,edf->ecf", buf, w3.astype(buf.dtype),
                    preferred_element_type=f32)
    act = (jax.nn.silu(h1) * h3).astype(buf.dtype)
    return jnp.einsum("ecf,efd->ecd", act, w2.astype(buf.dtype),
                      preferred_element_type=f32)


def _route_and_compute(tokens, router_w, w1, w3, w2, *, n_experts: int,
                       k: int, cap: int, e_lo: int,
                       acfg: Optional[ApproxConfig] = None):
    """Core dropless-ish routing on one device's tokens + expert slice.

    tokens: [T, D] (local); w*: [E_loc, ...] local expert slice starting
    at global expert index ``e_lo``.  Returns [T, D] contribution of the
    local experts (caller psums across the expert-sharded axis).
    """
    T, D = tokens.shape
    e_loc = w1.shape[0]
    # routing logits stay exact (top_k stability over tiny [T, E] work),
    # declared through the audited wrapper; the gate normalisation runs
    # through the registry's softmax_div family like every other softmax
    logits = exact_einsum("td,de->te", tokens.astype(jnp.float32), router_w)
    gval, gidx = jax.lax.top_k(logits, k)  # [T, k]
    gates = _router_gates(gval, acfg)

    fe = gidx.reshape(-1)  # [T*k] expert ids
    fg = gates.reshape(-1)
    order = jnp.argsort(fe)
    se = fe[order]
    sg = fg[order]
    tok_idx = order // k  # audit: exact — integer slot->token index math
    # audit: exact — integer binary-search midpoint inside searchsorted
    starts = jnp.searchsorted(se, jnp.arange(n_experts), side="left")
    pos = jnp.arange(T * k) - starts[se]

    local = (se >= e_lo) & (se < e_lo + e_loc)
    keep = (pos < cap) & local
    le = jnp.where(keep, se - e_lo, 0)
    lp = jnp.where(keep, pos, 0)

    gathered = tokens[tok_idx] * keep[:, None].astype(tokens.dtype)
    buf = jnp.zeros((e_loc, cap, D), tokens.dtype).at[le, lp].add(gathered)
    buf_out = _expert_compute(buf, w1, w3, w2, acfg)

    contrib = buf_out[le, lp] * (sg * keep)[:, None]
    out = jnp.zeros((T, D), jnp.float32).at[tok_idx].add(contrib)
    return out.astype(tokens.dtype)


def _route_a2a(tokens, router_w, w1, w3, w2, *, n_experts: int, k: int,
               cap: int, e_loc: int, model_axis: str,
               acfg: Optional[ApproxConfig] = None):
    """Production EP dispatch: tokens stay sequence-sharded; capacity
    buffers travel to expert owners via all_to_all and come back the same
    way.  tokens: [T_s, D] (this device's batch x seq shard); w*: local
    [E_loc, D, F] expert slice.  ``cap`` is the per-destination-rank slot
    budget.  Returns [T_s, D].
    """
    T_s, D = tokens.shape
    n_model = n_experts // e_loc  # audit: exact — integer rank-count math
    logits = exact_einsum("td,de->te", tokens.astype(jnp.float32), router_w)
    gval, gidx = jax.lax.top_k(logits, k)
    gates = _router_gates(gval, acfg)

    fe = gidx.reshape(-1)                      # global expert ids [T_s*k]
    fg = gates.reshape(-1)
    dest = fe // e_loc  # audit: exact — integer owning-rank index math
    order = jnp.argsort(dest)
    dest_s = dest[order]
    fe_s = fe[order]
    fg_s = fg[order]
    tok_idx = order // k  # audit: exact — integer slot->token index math
    starts = jnp.searchsorted(dest_s, jnp.arange(n_model), side="left")
    pos = jnp.arange(T_s * k) - starts[dest_s]
    keep = pos < cap
    dsto = jnp.where(keep, dest_s, 0)
    poso = jnp.where(keep, pos, 0)

    kf = keep[:, None].astype(tokens.dtype)
    send_tok = jnp.zeros((n_model, cap, D), tokens.dtype).at[dsto, poso].add(
        tokens[tok_idx] * kf)
    send_eid = jnp.zeros((n_model, cap), jnp.int32).at[dsto, poso].max(
        jnp.where(keep, fe_s % e_loc, 0))
    send_gate = jnp.zeros((n_model, cap), jnp.float32).at[dsto, poso].add(
        jnp.where(keep, fg_s, 0.0))

    recv_tok = jax.lax.all_to_all(send_tok, model_axis, 0, 0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid, model_axis, 0, 0, tiled=True)
    recv_gate = jax.lax.all_to_all(send_gate, model_axis, 0, 0, tiled=True)

    n_slots = n_model * cap
    flat_tok = recv_tok.reshape(n_slots, D)
    flat_eid = recv_eid.reshape(n_slots)
    buf = jnp.zeros((e_loc, n_slots, D), tokens.dtype).at[
        flat_eid, jnp.arange(n_slots)].set(flat_tok)
    buf_out = _expert_compute(buf, w1, w3, w2, acfg)
    ans = buf_out[flat_eid, jnp.arange(n_slots)].astype(tokens.dtype)
    ans = (ans.astype(jnp.float32) * recv_gate.reshape(n_slots, 1)).astype(
        tokens.dtype)
    back = jax.lax.all_to_all(ans.reshape(n_model, cap, D), model_axis,
                              0, 0, tiled=True)

    contrib = back[dsto, poso] * kf
    out = jnp.zeros((T_s, D), jnp.float32).at[tok_idx].add(
        contrib.astype(jnp.float32))
    return out.astype(tokens.dtype)


def moe_ffn(x, params, cfg: ModelConfig, ctx: ParallelCtx,
            d_ff: Optional[int] = None):
    """x: [B, S, D] -> MoE FFN output, same shape."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    router_w = params["router"].astype(jnp.float32)

    if ctx.mesh is None:
        T = B * S
        cap = max(1, int(-(-T * k * cfg.capacity_factor // E)))
        out = _route_and_compute(
            x.reshape(T, D), router_w, params["w1"], params["w3"], params["w2"],
            n_experts=E, k=k, cap=cap, e_lo=0, acfg=cfg.approx,
        ).reshape(B, S, D)
    else:
        mesh = ctx.mesh
        acfg = _manual_acfg(cfg.approx)  # device-local kernel choice
        batch_axes = ctx.data_axes if B > 1 else ()
        model_axis = ctx.rules.get("expert") or "model"
        fsdp_axis = ctx.rules.get("expert_ff")  # ff dim sharded at rest
        n_batch = 1
        for a in batch_axes:
            n_batch *= mesh.shape[a]
        T_loc = (B // n_batch) * S
        e_loc = E // mesh.shape[model_axis]

        # Two dispatch modes (weights are 2D-sharded [expert x ff] at rest):
        #  * weight-gather (training/prefill): tokens dominate — gather the
        #    ff dim of the local expert slice just-in-time, route locally;
        #  * token-gather (decode): weights dominate — gather the (tiny)
        #    token batch instead and compute on the resident weight shard,
        #    psum over both expert and ff partial axes.  At Jamba scale
        #    this replaces a 5.4 GB/step weight gather with a ~2 MB token
        #    gather.
        token_gather = T_loc * n_batch <= 4096 and fsdp_axis is not None
        seq_ax = ctx.rules.get("seq_act")
        n_model = mesh.shape[model_axis]
        a2a = (not token_gather and seq_ax == model_axis
               and S % n_model == 0)

        if a2a:
            # tokens stay sequence-sharded; dispatch via all_to_all.
            S_loc = S // n_model
            T_s = (B // n_batch) * S_loc
            cap = max(1, int(-(-T_s * k * cfg.capacity_factor // n_model)))

            def body(xl, rw, w1, w3, w2):
                if fsdp_axis is not None:
                    cdt = jnp.dtype(cfg.dtype)
                    w1 = jax.lax.all_gather(w1.astype(cdt), fsdp_axis,
                                            axis=2, tiled=True)
                    w3 = jax.lax.all_gather(w3.astype(cdt), fsdp_axis,
                                            axis=2, tiled=True)
                    w2 = jax.lax.all_gather(w2.astype(cdt), fsdp_axis,
                                            axis=1, tiled=True)
                bl, sl, _ = xl.shape
                out = _route_a2a(
                    xl.reshape(bl * sl, D), rw, w1, w3, w2,
                    n_experts=E, k=k, cap=cap, e_loc=e_loc,
                    model_axis=model_axis, acfg=acfg,
                )
                return out.reshape(bl, sl, D)

            wspec1 = PartitionSpec(model_axis, None, fsdp_axis)
            wspec2 = PartitionSpec(model_axis, fsdp_axis, None)
            out = shard_map(
                body,
                mesh=mesh,
                in_specs=(
                    PartitionSpec(batch_axes if batch_axes else None,
                                  model_axis, None),
                    PartitionSpec(None, None),
                    wspec1,
                    wspec1,
                    wspec2,
                ),
                out_specs=PartitionSpec(batch_axes if batch_axes else None,
                                        model_axis, None),
                check_vma=False,
            )(x, router_w, params["w1"], params["w3"], params["w2"])
            if cfg.shared_expert:
                out = out + mlp(x, params["shared"], cfg, ctx)
            return ctx.shard(out, "batch", "seq_act", "act_embed")

        if token_gather:
            T_glob = T_loc * n_batch
            cap = max(1, int(-(-T_glob * k * cfg.capacity_factor // E)))

            def body(xl, rw, w1, w3, w2):
                xg = xl
                for a in reversed(batch_axes):
                    xg = jax.lax.all_gather(xg, a, axis=0, tiled=True)
                mi = jax.lax.axis_index(model_axis)
                bg, sl, _ = xg.shape
                out = _route_and_compute(
                    xg.reshape(bg * sl, D), rw, w1, w3, w2,
                    n_experts=E, k=k, cap=cap, e_lo=mi * e_loc,
                    acfg=acfg,
                )
                out = jax.lax.psum(out, (model_axis, fsdp_axis))
                # take this device's batch rows back
                if batch_axes:
                    idx = jnp.int32(0)
                    for a in batch_axes:
                        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
                    out = jax.lax.dynamic_slice_in_dim(
                        out.reshape(bg, sl, D), idx * (B // n_batch), B // n_batch, 0)
                else:
                    out = out.reshape(bg, sl, D)
                return out
        else:
            cap = max(1, int(-(-T_loc * k * cfg.capacity_factor // E)))

            def body(xl, rw, w1, w3, w2):
                if fsdp_axis is not None:
                    # just-in-time FSDP gather of the per-expert ff dim
                    # (compute dtype: halves the gather bytes)
                    cdt = jnp.dtype(cfg.dtype)
                    w1 = jax.lax.all_gather(w1.astype(cdt), fsdp_axis,
                                            axis=2, tiled=True)
                    w3 = jax.lax.all_gather(w3.astype(cdt), fsdp_axis,
                                            axis=2, tiled=True)
                    w2 = jax.lax.all_gather(w2.astype(cdt), fsdp_axis,
                                            axis=1, tiled=True)
                # local expert range from this device's model-axis coordinate
                mi = jax.lax.axis_index(model_axis)
                bl, sl, _ = xl.shape
                out = _route_and_compute(
                    xl.reshape(bl * sl, D), rw, w1, w3, w2,
                    n_experts=E, k=k, cap=cap, e_lo=mi * e_loc,
                    acfg=acfg,
                )
                out = jax.lax.psum(out, model_axis)
                return out.reshape(bl, sl, D)

        wspec1 = PartitionSpec(model_axis, None, fsdp_axis)
        wspec2 = PartitionSpec(model_axis, fsdp_axis, None)
        out = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                PartitionSpec(batch_axes if batch_axes else None, None, None),
                PartitionSpec(None, None),
                wspec1,
                wspec1,
                wspec2,
            ),
            out_specs=PartitionSpec(batch_axes if batch_axes else None, None, None),
            check_vma=False,
        )(x, router_w, params["w1"], params["w3"], params["w2"])

    if cfg.shared_expert:
        out = out + mlp(x, params["shared"], cfg, ctx)
    return ctx.shard(out, "batch", "seq_act", "act_embed")
