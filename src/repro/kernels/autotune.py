"""Device-measured KernelSpec autotuner with a committed tuning cache.

The ROADMAP's top open item, AMG-style (arxiv 2310.15495): instead of
trusting the static block heuristics, *search* the legal
``(bm, bn, bk, pipeline.depth)`` space per kernel family and shape
class, score every candidate — on a TPU by actually timing the kernel,
elsewhere with a deterministic static cost model — and persist the
winners in a committed, versioned ``TUNE_baseline.json`` (the same
ratchet discipline as ``BENCH_baseline.json``: regenerate with
``python -m benchmarks.run --retune``, review the diff like code, CI
diff-checks the file for uncommitted drift).

Cache keying.  One entry per ``(family, shape class, scheme,
epilogue kind)`` under a per-``platform`` subtree; the shape class
buckets each problem dim to the next power of two above its minimum
hardware tile, so one tuned entry covers a band of real shapes and the
key is a pure function of python ints — stable across jax pins.
:func:`repro.kernels.spec.resolve_spec` consults the cache through
:func:`cached_spec` with the documented precedence *explicit spec field
> cache hit > heuristic fallback (off-TPU / cache miss)*.

Legality before cost.  Candidates are pre-filtered through the same two
gates production calls hit: the wrappers' ``kernels/budget.py`` working-
set checks (an oversized candidate raises before any kernel is built)
and the static RPD005-008 geometry audit over the captured
``pallas_call`` (:mod:`repro.analysis.capture` +
``repro.analysis.kernel_audit.audit_call``) — so the tuner never times,
or commits, an illegal spec.  The kernel auditor in turn audits every
*committed* entry as a ``tuned/...`` variant (:func:`tuned_audit_
variants`), closing the loop: RPD005-008 gate the cache contents in CI.

Objectives.  On the target device (``platform == "tpu"`` and jax is
actually running on a TPU) candidates are wall-clock timed
(``objective: "device-measured"``).  Everywhere else — the CI host, a
dev laptop — scoring falls back to a deterministic roofline-style cost
model (``objective: "static-model"``): per-step HBM traffic and compute
either overlap (depth >= 2, paying a ``depth-1``-tile pipeline fill) or
serialize (depth 1), plus a per-grid-step scheduling overhead.  The
model only ranks candidates; its absolute numbers are nominal.  Being
deterministic, a ``--retune`` on the CI host reproduces the committed
cpu subtree byte-for-byte, which is what makes the drift check viable.

Search strategy is pluggable: :class:`ExhaustiveSearch` walks the whole
legal grid (it is small); the ``search(candidates, evaluate)``
interface is what a Bayesian strategy (AMG's endgame) would implement
by subsampling candidates and modelling ``evaluate``.

Numerics contract: for ``log_matmul`` and the ``fused_div`` family
every knob here is schedule-only — any committed spec is bit-exact
against the jnp oracle (asserted in ``tests/test_autotune.py``).  For
``flash_attn``, ``depth`` is schedule-only but ``bk`` (the cache chunk
size) re-chunks the online-softmax max, so that family keeps its
existing tight-allclose parity contract vs ``decode_attn_ref``
(bit-exact when the chunking is unchanged — see
``kernels/flash_attn/flash_attn.py``).
"""
from __future__ import annotations

import functools
import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.kernels import budget
from repro.kernels.spec import (
    KernelSpec,
    PipelineSpec,
    _rebalance_norm_matmul,
    resolve_spec,
)

__all__ = [
    "TuningCache",
    "Workload",
    "ExhaustiveSearch",
    "workloads",
    "shape_class",
    "entry_key",
    "legal_candidates",
    "static_cost",
    "measure_candidate",
    "cached_spec",
    "get_tuning_cache",
    "set_tuning_cache",
    "default_cache_path",
    "tuned_audit_variants",
    "retune",
]

CACHE_VERSION = 1
CACHE_BASENAME = "TUNE_baseline.json"
ENV_VAR = "RAPID_TUNE_CACHE"

_CONTRACT = (
    "Committed KernelSpec tuning cache.  platforms.<platform>.entries "
    "maps '<family>/<shape class>/<scheme>/<epilogue kind>' to the "
    "winning (bm, bn, bk, depth) for that workload band, selected by "
    "repro.kernels.autotune over the legal candidate grid (budget + "
    "RPD005-008 pre-filtered; objective 'device-measured' on real "
    "hardware, deterministic 'static-model' elsewhere).  "
    "resolve_spec fills unset KernelSpec fields from here with "
    "precedence explicit > cache > heuristic.  Regenerate with "
    "'PYTHONPATH=src python -m benchmarks.run --retune' and commit the "
    "diff; CI re-runs the host-platform retune and fails on drift, and "
    "the kernel auditor re-checks every entry as a tuned/ variant."
)

_ENTRY_FIELDS = ("family", "shapes", "scheme", "epilogue_kind",
                 "bm", "bn", "bk", "depth", "cost_us", "objective")

# ---------------------------------------------------------------------------
# cache keying: shape classes + entry keys (pure python ints -> stable
# across jax pins and platforms)
# ---------------------------------------------------------------------------


def _bucket(v: int, tile: int) -> int:
    """Round ``v`` up to ``tile``, then to the next power of two."""
    v = budget.round_up(max(int(v), 1), tile)
    return 1 << (v - 1).bit_length()


def shape_class(family: str, shapes: Sequence[int]) -> str:
    """Bucketed shape-class label — part of the tuning-cache key."""
    s = [int(v) for v in shapes]
    if family == "log_matmul":
        m, n, k = s
        return (f"{_bucket(m, budget.SUBLANE)}x{_bucket(n, budget.LANE)}"
                f"x{_bucket(k, budget.LANE)}")
    if family in ("fused_softmax", "fused_rms", "fused_div_rowbcast"):
        m, n = s[:2]
        return f"{_bucket(m, budget.SUBLANE)}x{_bucket(n, budget.LANE)}"
    if family == "flash_attn":
        rows, c, g, hd = s
        return (f"r{_bucket(rows, budget.SUBLANE)}c{_bucket(c, budget.LANE)}"
                f"g{_bucket(g, budget.SUBLANE)}d{_bucket(hd, budget.LANE)}")
    raise KeyError(f"unknown kernel family {family!r}")


def entry_key(family: str, shapes: Sequence[int], scheme: Optional[str],
              epilogue_kind: str) -> str:
    """'<family>/<shape class>/<scheme>/<epilogue kind>' cache key."""
    return (f"{family}/{shape_class(family, shapes)}/"
            f"{scheme or 'exact'}/{epilogue_kind}")


# ---------------------------------------------------------------------------
# the committed cache document
# ---------------------------------------------------------------------------


class TuningCache:
    """Versioned winners document (``TUNE_baseline.json``).

    Layout::

        {"version": 1, "contract": "...",
         "platforms": {"cpu": {"objective": ..., "entries": {key: entry}},
                       "tpu": {...}}}

    ``load`` validates hard: corrupt JSON or a schema violation raises
    ``ValueError`` naming the problem, and a version mismatch is
    *stale* — the error says to regenerate with ``--retune``.  A
    missing file is an empty cache (fresh checkout, heuristics apply).
    """

    def __init__(self, doc: dict):
        self.doc = doc

    @classmethod
    def empty(cls) -> "TuningCache":
        return cls({"version": CACHE_VERSION, "contract": _CONTRACT,
                    "platforms": {}})

    @classmethod
    def load(cls, path: os.PathLike | str) -> "TuningCache":
        try:
            text = Path(path).read_text()
        except FileNotFoundError:
            return cls.empty()
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"corrupt tuning cache {path}: not valid JSON ({e}); "
                "regenerate with 'python -m benchmarks.run --retune'")
        if not isinstance(doc, dict) or "platforms" not in doc:
            raise ValueError(
                f"corrupt tuning cache {path}: missing 'platforms' section; "
                "regenerate with 'python -m benchmarks.run --retune'")
        if doc.get("version") != CACHE_VERSION:
            raise ValueError(
                f"stale tuning cache {path}: version {doc.get('version')!r} "
                f"!= {CACHE_VERSION}; regenerate with "
                "'python -m benchmarks.run --retune'")
        for platform, sub in doc["platforms"].items():
            entries = (sub or {}).get("entries", {})
            for key, e in entries.items():
                missing = [f for f in _ENTRY_FIELDS if f not in e]
                if missing:
                    raise ValueError(
                        f"corrupt tuning cache {path}: entry "
                        f"{platform}/{key} missing fields {missing}")
                for f in ("bm", "bn", "bk"):
                    if e[f] is not None and not isinstance(e[f], int):
                        raise ValueError(
                            f"corrupt tuning cache {path}: entry "
                            f"{platform}/{key} field {f}={e[f]!r} is not "
                            "an int or null")
                if not isinstance(e["depth"], int):
                    raise ValueError(
                        f"corrupt tuning cache {path}: entry "
                        f"{platform}/{key} depth={e['depth']!r} is not an "
                        "int")
        return cls(doc)

    def platforms(self) -> Tuple[str, ...]:
        return tuple(self.doc.get("platforms", {}))

    def entries(self, platform: str) -> Dict[str, dict]:
        sub = self.doc.get("platforms", {}).get(platform) or {}
        return sub.get("entries", {})

    def lookup(self, platform: str, key: str) -> Optional[dict]:
        return self.entries(platform).get(key)

    def set_platform(self, platform: str, entries: Dict[str, dict], *,
                     objective: str) -> None:
        """Replace one platform's subtree (a retune touches only the
        platform it actually scored on)."""
        self.doc.setdefault("platforms", {})[platform] = {
            "objective": objective, "entries": entries}

    def save(self, path: os.PathLike | str) -> None:
        with open(path, "w") as fh:
            json.dump(self.doc, fh, indent=2, sort_keys=True)
            fh.write("\n")


def default_cache_path() -> Path:
    """``$RAPID_TUNE_CACHE`` or ``TUNE_baseline.json`` at the repo root."""
    env = os.environ.get(ENV_VAR)
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / CACHE_BASENAME


_ACTIVE: Optional[TuningCache] = None


def get_tuning_cache() -> TuningCache:
    """The memoized process-wide cache ``resolve_spec`` consults."""
    global _ACTIVE
    if _ACTIVE is None:
        _ACTIVE = TuningCache.load(default_cache_path())
    return _ACTIVE


def set_tuning_cache(cache: Optional[TuningCache]) -> None:
    """Swap the active cache (``None`` = lazily reload from disk)."""
    global _ACTIVE
    _ACTIVE = cache


@functools.lru_cache(maxsize=1)
def _default_platform() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - no runtime at all
        return "cpu"


def cached_spec(family: str, shapes: Sequence[int], *,
                scheme: Optional[str], epilogue_kind: str,
                platform: Optional[str] = None) -> Optional[dict]:
    """Tuning-cache hit (an entry dict) or ``None`` — what
    :func:`repro.kernels.spec.resolve_spec` calls on a cache-eligible
    dispatch.  A corrupt/stale committed cache raises here, loudly, on
    the first dispatch that consults it."""
    cache = get_tuning_cache()
    platform = platform or _default_platform()
    entries = cache.entries(platform)
    if not entries:
        return None
    return entries.get(entry_key(family, shapes, scheme, epilogue_kind))


# ---------------------------------------------------------------------------
# tuned workloads: one per kernel family x bench shape class
# ---------------------------------------------------------------------------


def _operand(shape, dtype=None):
    """Deterministic non-trivial f32 data (no RNG: retunes reproduce)."""
    import jax.numpy as jnp
    n = 1
    for d in shape:
        n *= int(d)
    v = (jnp.arange(n, dtype=jnp.float32) % 61 - 30.0) / 8.0 + 0.25
    return v.reshape(shape)


@dataclass(frozen=True)
class Workload:
    """One tunable (family, shapes, scheme, epilogue kind) workload."""

    family: str
    shapes: Tuple[int, ...]
    scheme: Optional[str]
    epilogue_kind: str

    @property
    def key(self) -> str:
        return entry_key(self.family, self.shapes, self.scheme,
                         self.epilogue_kind)

    def epilogue(self):
        """The Epilogue object (log_matmul norm/act kinds), else None."""
        if self.family != "log_matmul" or self.epilogue_kind in ("plain",
                                                                 "act"):
            return None
        from repro.core.backend import Epilogue
        norm, _, pre = self.epilogue_kind.partition("+")
        return Epilogue(norm=norm, div_scheme="rapid9",
                        keep_prenorm=pre == "pre")

    def drive(self, spec: KernelSpec, *, interpret: bool = False):
        """Run the family wrapper once with ``spec``; returns the output
        (callers block on it when timing).  ``interpret=False`` under
        the capture shim records real dimension_semantics off-TPU."""
        import jax.numpy as jnp
        if self.family == "log_matmul":
            m, n, k = self.shapes
            kw = {}
            if self.epilogue_kind == "act":
                kw = dict(bias=jnp.zeros((n,), jnp.float32),
                          activation="silu")
            elif self.epilogue_kind != "plain":
                kw = dict(epilogue=self.epilogue())
            from repro.kernels.log_matmul.ops import log_matmul
            return log_matmul(_operand((m, k)), _operand((k, n)),
                              self.scheme, spec=spec, interpret=interpret,
                              **kw)
        if self.family == "fused_softmax":
            from repro.kernels.fused_div.ops import fused_softmax_div
            return fused_softmax_div(_operand(self.shapes), self.scheme,
                                     spec=spec, interpret=interpret)
        if self.family == "fused_rms":
            from repro.kernels.fused_div.ops import fused_rms_div
            return fused_rms_div(_operand(self.shapes), 1e-6, self.scheme,
                                 spec=spec, interpret=interpret)
        if self.family == "fused_div_rowbcast":
            from repro.kernels.fused_div.ops import fused_elementwise_div
            m, n = self.shapes
            denom = _operand((m, 1)) + 8.0  # strictly positive rows
            return fused_elementwise_div(_operand((m, n)), denom,
                                         self.scheme, spec=spec,
                                         interpret=interpret)
        if self.family == "flash_attn":
            rows, c, g, hd = self.shapes
            from repro.kernels.flash_attn.ops import flash_decode_attn
            return flash_decode_attn(
                _operand((rows, 1, g, hd)),
                _operand((rows, c, 1, hd)),
                _operand((rows, c, 1, hd)),
                jnp.zeros((rows, c), jnp.int32), c, 0, self.scheme,
                spec=spec, interpret=interpret)
        raise KeyError(f"unknown kernel family {self.family!r}")


#: matmul bench shape classes (mirrors the kernel auditor's sweep)
MATMUL_SHAPES: Dict[str, Tuple[int, int, int]] = {
    "square512": (512, 512, 512),
    "ktail130": (256, 256, 130),
    "skinny_m4": (4, 512, 512),
    "ntail300": (64, 300, 256),
    "deepk2048": (64, 256, 2048),
}


def workloads() -> List[Workload]:
    """Every tuned workload: all families across the bench shape classes.

    The general elementwise-div fallback and the integer
    ``rapid_mul``/``rapid_div`` units have no spec geometry to tune
    (fixed minimum tiles / flat maps) and are deliberately absent.
    """
    ws = [Workload("log_matmul", s, "rapid10", "plain")
          for s in MATMUL_SHAPES.values()]
    for kind in ("act", "rms", "rms+pre", "softmax"):
        ws.append(Workload("log_matmul", (512, 512, 512), "rapid10", kind))
    ws.append(Workload("log_matmul", (128, 4096, 512), "rapid10", "rms"))
    ws += [
        Workload("fused_softmax", (64, 1000), "rapid9", "plain"),
        Workload("fused_softmax", (8, 128), "rapid9", "plain"),
        Workload("fused_rms", (32, 300), "rapid9", "plain"),
        Workload("fused_div_rowbcast", (128, 4096), "rapid9", "plain"),
        Workload("flash_attn", (8, 256, 4, 64), "rapid9", "plain"),
        Workload("flash_attn", (2, 128, 8, 128), None, "plain"),
    ]
    return ws


# ---------------------------------------------------------------------------
# candidate grids + legality pre-filter
# ---------------------------------------------------------------------------

_BM_GRID = (8, 64, 128, 256)
_BN_GRID = (128, 256)
_BK_GRID = (128, 256, 512)
_BC_GRID = (128, 256, 512)   # flash_attn cache chunk
_DEPTH_GRID = (1, 2, 3)


def _raw_candidates(w: Workload) -> Iterable[KernelSpec]:
    if w.family == "log_matmul":
        for bm in _BM_GRID:
            for bn in _BN_GRID:
                for bk in _BK_GRID:
                    for depth in _DEPTH_GRID:
                        yield KernelSpec(bm=bm, bn=bn, bk=bk,
                                         pipeline=PipelineSpec(depth=depth))
    elif w.family == "flash_attn":
        for bk in _BC_GRID:
            for depth in _DEPTH_GRID:
                yield KernelSpec(bk=bk, pipeline=PipelineSpec(depth=depth))
    else:
        for bm in _BM_GRID:
            for depth in _DEPTH_GRID:
                yield KernelSpec(bm=bm, pipeline=PipelineSpec(depth=depth))


def _geometry_legal(w: Workload, spec: KernelSpec) -> bool:
    """Gate 2: capture the candidate's pallas_call(s) and run the
    RPD005-008 geometry audit over them; any finding disqualifies.
    Gate 1 (the wrapper's budget.check_working_set) shows up here as
    the wrapper raising before a call is captured."""
    from repro.analysis.capture import capture_pallas_calls
    from repro.analysis.kernel_audit import audit_call
    try:
        with capture_pallas_calls() as calls:
            w.drive(spec, interpret=False)
    except Exception:
        return False
    if not calls:
        return False
    for call in calls:
        findings, _ = audit_call(call, f"tune/{w.key}", w.family)
        if findings:
            return False
    return True


def legal_candidates(w: Workload) -> List[KernelSpec]:
    """The pre-filtered candidate list the search strategy scores.

    Candidates are canonicalized first (the norm-epilogue row/slab
    rebalance collapses many raw grid points to one geometry) and
    deduplicated, then pushed through both legality gates, so the tuner
    never evaluates — let alone times — an illegal spec.
    """
    norm = w.family == "log_matmul" and w.epilogue_kind not in ("plain",
                                                                "act")
    out: List[KernelSpec] = []
    seen = set()
    for spec in _raw_candidates(w):
        if norm:
            bm, bn, bk = _rebalance_norm_matmul(
                spec.bm, spec.bn, spec.bk, w.shapes[1])
            spec = KernelSpec(bm=bm, bn=bn, bk=bk, pipeline=spec.pipeline)
        sig = (spec.bm, spec.bn, spec.bk, spec.depth)
        if sig in seen:
            continue
        seen.add(sig)
        if _geometry_legal(w, spec):
            out.append(spec)
    return out


# ---------------------------------------------------------------------------
# objectives: deterministic static cost model / on-device wall time
# ---------------------------------------------------------------------------

_BW = 8.0e11       # nominal HBM bytes/s
_FLOPS = 2.0e13    # nominal lane ops/s (log-domain MACs)
_STEP_OVH = 2.0e-6  # per-grid/pipeline-step scheduling overhead (s)


def _model_time(copy_bytes: float, compute_ops: float, steps: int,
                depth: int, tile_copy_bytes: float) -> float:
    """Roofline-style schedule model shared by every family.

    Depth >= 2 overlaps the next tile's DMA with the current tile's
    compute (paying a ``depth-1``-tile pipeline fill); depth 1
    serializes copy and compute.  Only the *ranking* matters.
    """
    copy_t = copy_bytes / _BW
    compute_t = compute_ops / _FLOPS
    if depth >= 2:
        fill = (depth - 1) * (tile_copy_bytes / _BW)
        return max(copy_t, compute_t) + fill + _STEP_OVH * steps
    return copy_t + compute_t + _STEP_OVH * steps


def static_cost(w: Workload, spec: KernelSpec) -> float:
    """Deterministic modelled seconds for one (workload, candidate)."""
    e = budget.ELEM_BYTES
    if w.family == "log_matmul":
        m, n, k = w.shapes
        bm, bn, bk, depth = spec.bm, spec.bn, spec.bk, spec.depth
        mp = budget.round_up(m, bm)
        np_ = budget.round_up(n, bn)
        kp = budget.round_up(k, bk)
        steps = (mp // bm) * (np_ // bn) * (kp // bk)
        tile = (bm * bk + bk * bn) * e
        out_rows = 2 if w.epilogue_kind.endswith("+pre") else 1
        copy = steps * tile + out_rows * mp * np_ * e
        compute = float(mp) * np_ * kp
        return _model_time(copy, compute, steps, depth, tile)
    if w.family == "flash_attn":
        rows, c, g, hd = w.shapes
        bc, depth = spec.bk, spec.depth
        gp = budget.round_up(g, budget.SUBLANE)
        hdp = budget.round_up(hd, budget.LANE)
        cpad = budget.round_up(c, bc)
        nchunks = cpad // bc
        steps = rows * nchunks
        tile = (2 * bc * hdp + bc) * e
        copy = rows * ((2 * cpad * hdp + cpad) * e + 2 * gp * hdp * e)
        compute = 2.0 * rows * gp * cpad * hdp
        return _model_time(copy, compute, steps, depth, tile)
    m, n = w.shapes[:2]
    bm, depth = spec.bm, spec.depth
    npad = budget.round_up(n, budget.LANE)
    mp = budget.round_up(m, bm)
    steps = mp // bm
    tile = 2 * bm * npad * e
    copy = 2 * mp * npad * e
    compute = 4.0 * mp * npad
    return _model_time(copy, compute, steps, depth, tile)


def measure_candidate(w: Workload, spec: KernelSpec, *,
                      reps: int = 3) -> float:
    """Wall-clock seconds on the actual device (min over ``reps`` after
    a compile/warmup run) — the TPU objective."""
    import jax
    jax.block_until_ready(w.drive(spec, interpret=False))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(w.drive(spec, interpret=False))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# pluggable search
# ---------------------------------------------------------------------------


class ExhaustiveSearch:
    """Walk the whole legal grid; deterministic first-wins argmin.

    The strategy interface — ``search(candidates, evaluate) -> (best,
    cost, n_evaluated)`` over an ordered candidate list and a pure
    scoring callable — is what a Bayesian strategy (AMG arxiv
    2310.15495) would implement instead: subsample ``candidates``,
    model ``evaluate``, stop early.  Exhaustive is exact and, over the
    pre-filtered grids here (tens of points), cheap.
    """

    name = "exhaustive"

    def search(self, candidates: Sequence[KernelSpec],
               evaluate: Callable[[KernelSpec], float]
               ) -> Tuple[KernelSpec, float, int]:
        best: Optional[KernelSpec] = None
        best_cost = float("inf")
        n = 0
        for cand in candidates:
            cost = float(evaluate(cand))
            n += 1
            if best is None or cost < best_cost:
                best, best_cost = cand, cost
        if best is None:
            raise ValueError("no legal candidates to search")
        return best, best_cost, n


# ---------------------------------------------------------------------------
# retune: regenerate one platform subtree of the committed cache
# ---------------------------------------------------------------------------


def retune(platform: Optional[str] = None, *,
           path: Optional[os.PathLike | str] = None,
           strategy: Optional[ExhaustiveSearch] = None,
           verbose: bool = True) -> dict:
    """Re-search every workload and rewrite ``platform``'s cache subtree.

    Only the retuned platform's entries are replaced; other platforms'
    committed winners are preserved (a CPU CI retune must not clobber
    TPU-measured entries).  Candidates are timed on-device only when
    the retune targets the platform jax is actually running on AND that
    platform is a TPU; otherwise the deterministic static model scores
    them, keeping the CI drift check byte-stable.  Returns a summary
    dict (per-key winners + counts).
    """
    platform = platform or _default_platform()
    strategy = strategy or ExhaustiveSearch()
    path = Path(path) if path is not None else default_cache_path()
    try:
        cache = TuningCache.load(path)
    except ValueError as e:
        if verbose:
            print(f"retune: discarding unreadable cache ({e})")
        cache = TuningCache.empty()
    measured = platform == "tpu" and _default_platform() == "tpu"
    objective = "device-measured" if measured else "static-model"
    entries: Dict[str, dict] = {}
    for w in workloads():
        cands = legal_candidates(w)
        evaluate = ((lambda c, w=w: measure_candidate(w, c)) if measured
                    else (lambda c, w=w: static_cost(w, c)))
        best, cost, n = strategy.search(cands, evaluate)
        entries[w.key] = {
            "family": w.family,
            "shapes": list(w.shapes),
            "scheme": w.scheme,
            "epilogue_kind": w.epilogue_kind,
            "bm": best.bm, "bn": best.bn, "bk": best.bk,
            "depth": best.depth,
            "cost_us": round(cost * 1e6, 3),
            "objective": objective,
        }
        if verbose:
            print(f"retune[{platform}] {w.key}: bm={best.bm} bn={best.bn} "
                  f"bk={best.bk} depth={best.depth} "
                  f"({n} legal candidates, {objective} {cost * 1e6:.1f}us)")
    cache.set_platform(platform, entries, objective=objective)
    cache.save(path)
    set_tuning_cache(None)  # new winners visible to the next resolve
    if verbose:
        print(f"retune: wrote {len(entries)} {platform} entries to {path}")
    return {"platform": platform, "objective": objective, "path": str(path),
            "entries": entries}


# ---------------------------------------------------------------------------
# auditor integration: every committed entry is an audited variant
# ---------------------------------------------------------------------------


def entry_spec(entry: dict) -> KernelSpec:
    """The concrete KernelSpec a cache entry pins."""
    return KernelSpec(bm=entry.get("bm"), bn=entry.get("bn"),
                      bk=entry.get("bk"),
                      pipeline=PipelineSpec(depth=int(entry["depth"])))


def tuned_audit_variants() -> List[Tuple[str, str, Callable[[], None]]]:
    """(variant_id, family, driver) rows for every committed tuned spec.

    Consumed by ``repro.analysis.kernel_audit.iter_variants`` so the
    RPD005-008 geometry checks (and ``PIPELINE_REPORT.json``) gate the
    cache contents, not just the heuristic defaults.  Identical entries
    across platforms dedupe to one ``tuned/<key>`` variant; a platform
    whose winner diverges gets its own ``tuned/<key>@<platform>`` row.
    An absent cache contributes nothing; a corrupt one raises (the
    audit job should fail loudly, same as dispatch would).
    """
    cache = TuningCache.load(default_cache_path())
    rows: List[Tuple[str, str, Callable[[], None]]] = []
    seen: Dict[str, tuple] = {}
    for platform in sorted(cache.platforms()):
        for key, e in sorted(cache.entries(platform).items()):
            sig = (e.get("bm"), e.get("bn"), e.get("bk"), e.get("depth"),
                   tuple(e.get("shapes", ())))
            if seen.get(key) == sig:
                continue
            vid = f"tuned/{key}" if key not in seen else \
                f"tuned/{key}@{platform}"
            seen.setdefault(key, sig)
            w = Workload(e["family"], tuple(e["shapes"]), e.get("scheme"),
                         e["epilogue_kind"])
            spec = entry_spec(e)
            rows.append((vid, e["family"],
                         functools.partial(w.drive, spec, interpret=False)))
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.kernels.autotune",
        description="KernelSpec autotuner (winners -> TUNE_baseline.json)")
    ap.add_argument("--platform", default=None,
                    help="platform subtree to retune (default: the host's)")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help=f"cache file (default: $"
                         f"{ENV_VAR} or {CACHE_BASENAME} at the repo root)")
    ap.add_argument("--list", action="store_true",
                    help="print the tuned workloads and exit")
    args = ap.parse_args(argv)
    if args.list:
        for w in workloads():
            print(w.key)
        return 0
    retune(args.platform, path=args.cache)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
