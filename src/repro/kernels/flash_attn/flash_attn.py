"""Pallas TPU kernel: fused flash-decode attention with the RAPID divider.

One grid step owns one (batch, kv-head) row: the query block stays
VMEM-resident while the kernel scans the cache in ``bc``-slot chunks,
keeping running online-softmax stats (m, l, acc) and finishing with the
floored combine divide — through ``float_approx.log_div_f32`` when a
RAPID scheme is set.  This replaces the separate score-matmul + mask +
softmax-stats + value-matmul + combine passes of the jnp decode path
with a single kernel whose intermediates never visit HBM.

The cache chunks are software-pipelined exactly like the other kernel
families: k / v / slot-position chunks live in ANY (HBM) memory and
rotate through ``depth`` VMEM scratch slots via explicit
``make_async_copy`` DMAs, so chunk c+depth-1's fetch overlaps chunk c's
compute.  Depth 1 degenerates to a strictly sequential fetch-compute
loop (the same kernel body; no separate formulation).

Numerics: the score/value contractions are exact (MXU dot_generals, as
``models/layers.py`` keeps activation-activation contractions exact);
the online chunked max can differ from the jnp reference's global max
by reassociation, so parity vs :func:`..ref.decode_attn_ref` is tight
allclose, not bit-exact — except when the whole cache fits one chunk,
where the schedules coincide.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import float_approx as fa
from repro.kernels.fused_div.ref import SOFTMAX_FLOOR

__all__ = ["flash_decode_pallas"]


def _flash_kernel(q_ref, posq_ref, k_hbm, v_hbm, sp_hbm, *rest, bc: int,
                  nc: int, depth: int, window: int, floor: float,
                  has_lut: bool):
    refs = list(rest)
    dlut_ref = refs.pop(0) if has_lut else None
    o_ref, k_scr, v_scr, sp_scr, k_sem, v_sem, sp_sem = refs
    r = pl.program_id(0)

    def dmas(slot, c):
        sl = pl.ds(c * bc, bc)
        return (
            pltpu.make_async_copy(k_hbm.at[r, sl, :], k_scr.at[slot],
                                  k_sem.at[slot]),
            pltpu.make_async_copy(v_hbm.at[r, sl, :], v_scr.at[slot],
                                  v_sem.at[slot]),
            pltpu.make_async_copy(sp_hbm.at[r, sl], sp_scr.at[slot],
                                  sp_sem.at[slot]),
        )

    for d in range(min(depth - 1, nc)):
        for cp in dmas(d % depth, d):
            cp.start()

    q = q_ref[0]            # [Gp, hdp]
    posq = posq_ref[r, 0]   # whole-array resident; one scalar per row
    gp, hdp = q.shape

    def step(c, carry):
        m, l, acc = carry
        slot = jax.lax.rem(c, depth)
        nxt = c + depth - 1

        @pl.when(nxt < nc)
        def _prefetch():
            for cp in dmas(jax.lax.rem(nxt, depth), nxt):
                cp.start()

        for cp in dmas(slot, c):
            cp.wait()
        kb = k_scr[slot]        # [bc, hdp]
        vb = v_scr[slot]
        spb = sp_scr[slot]      # [bc]
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = spb <= posq
        if window:
            mask &= spb > posq - window
        s = jnp.where(mask[None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.where(jnp.isfinite(m_new), jnp.exp(s - m_new), 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        l = l * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, vb, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc = acc * corr + pv
        return m_new, l, acc

    m0 = jnp.full((gp, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((gp, 1), jnp.float32)
    a0 = jnp.zeros((gp, hdp), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, nc, step, (m0, l0, a0))
    l = jnp.maximum(l, floor)
    if has_lut:
        out = fa.log_div_f32(acc, l, dlut_ref[...])
    else:
        out = acc / l
    o_ref[0] = out


@functools.partial(
    jax.jit,
    static_argnames=("bc", "depth", "window", "floor", "interpret"),
)
def flash_decode_pallas(
    q: jnp.ndarray,       # [R, Gp, hdp] f32, pre-scaled
    k: jnp.ndarray,       # [R, Cp, hdp] f32
    v: jnp.ndarray,       # [R, Cp, hdp] f32
    sp: jnp.ndarray,      # [R, Cp] int32 (INT32_MAX = empty/pad slot)
    posq: jnp.ndarray,    # [R, 1] int32
    div_lut: jnp.ndarray | None = None,
    *,
    bc: int = 128,
    depth: int = 2,
    window: int = 0,
    floor: float = SOFTMAX_FLOOR,
    interpret: bool = False,
):
    """Fused decode attention over pre-padded rows; Cp % bc == 0."""
    r, gp, hdp = q.shape
    cp = k.shape[1]
    nc = cp // bc
    has_lut = div_lut is not None
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    in_specs = [
        pl.BlockSpec((1, gp, hdp), lambda i: (i, 0, 0)),
        pl.BlockSpec((r, 1), lambda i: (0, 0)),      # tiny: stays resident
        any_spec,                                    # k: manual DMA
        any_spec,                                    # v: manual DMA
        any_spec,                                    # slot positions
    ]
    operands = [q, posq, k, v, sp]
    if has_lut:
        in_specs.append(pl.BlockSpec((256,), lambda i: (0,)))
        operands.append(div_lut)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bc=bc, nc=nc, depth=depth,
                          window=window, floor=floor, has_lut=has_lut),
        grid=(r,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, gp, hdp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, gp, hdp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((depth, bc, hdp), jnp.float32),
            pltpu.VMEM((depth, bc, hdp), jnp.float32),
            pltpu.VMEM((depth, bc), jnp.int32),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel",))
        ) if not interpret else None,
        interpret=interpret,
    )(*operands)
