"""jnp reference semantics for single-token (decode) attention.

The canonical math the flash kernel reproduces: one query token per
(batch, kv-head) row attends to a cache of ``C`` slots whose absolute
positions live in ``slot_positions`` (``jnp.iinfo(int32).max`` marks an
empty slot, which causality masks out).  ``decode_stats`` is the exact
score/softmax-stats/value contraction — shared with the sequence-sharded
flash-decode path in ``models/layers.py``, whose per-shard stats are
these stats psum-combined — and ``decode_attn_ref`` finishes with the
floored softmax divide (RAPID approximate when ``scheme`` is set).  The
score and value contractions intentionally stay exact (the paper
approximates weight matmuls and divides, not activation-activation
contractions); only the combine divide is approximate.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.core.ops import exact_einsum
from repro.kernels.fused_div.ref import SOFTMAX_FLOOR

__all__ = ["SOFTMAX_FLOOR", "canon_posq", "decode_stats", "decode_attn_ref"]


def canon_posq(pos) -> jnp.ndarray:
    """Current-position arg (scalar | [B] | [B, 1]) -> [*, 1]-broadcastable."""
    posq = jnp.asarray(pos)
    if posq.ndim == 1:
        posq = posq[:, None]
    return posq


def decode_stats(qf, kc, vc, sp, posq, window: int):
    """Per-row softmax stats (m, l, acc) for one decode step.

    qf: [B, KV, G, hd] pre-scaled f32 queries; kc/vc: [B, C, KV, hd];
    sp: [B, C] absolute slot positions; posq: scalar or [B, 1].
    Fully-masked rows yield m = -inf, l = 0, acc = 0.
    """
    s = exact_einsum("bkgh,bckh->bkgc", qf, kc.astype(jnp.float32))
    mask = sp <= posq
    if window:
        mask &= sp > posq - window
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1)
    p = jnp.where(jnp.isfinite(m)[..., None], jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = exact_einsum("bkgc,bckh->bkgh", p, vc.astype(jnp.float32))
    return m, l, acc


def decode_attn_ref(qf, k_cache, v_cache, slot_positions, pos, window: int,
                    scheme: Optional[str], *,
                    floor: float = SOFTMAX_FLOOR) -> jnp.ndarray:
    """Exact-stats decode attention with the (floored) softmax combine.

    Returns [B, KV, G, hd] f32.  The same floor as the fused softmax_div
    kernels, so fully-masked rows divide 0/floor = 0 instead of trapping.
    """
    posq = canon_posq(pos)
    m, l, acc = decode_stats(qf, k_cache, v_cache, slot_positions, posq,
                             window)
    l = jnp.maximum(l, floor)
    if scheme:
        return fa.approx_div(acc, l[..., None], scheme)
    return acc / l[..., None]  # audit: exact — the exact-softmax arm
