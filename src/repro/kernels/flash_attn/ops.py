"""Public wrapper for the flash-decode kernel (layout + pad + dispatch).

Model-shaped operands ([B, KV, G, hd] queries against [B, C, KV, hd]
caches) are flattened to one row per (batch, kv-head), the cache is
transposed row-major and padded to the chunk grid, and the kernel runs
one grid step per row.  Pad slots carry ``INT32_MAX`` positions, which
the causality mask removes — the same empty-slot convention the ring
caches already use — so padding never perturbs the softmax stats.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.kernels import budget
from repro.kernels.flash_attn.flash_attn import flash_decode_pallas
from repro.kernels.flash_attn.ref import SOFTMAX_FLOOR, canon_posq
from repro.kernels.spec import KernelSpec, as_kernel_spec, resolve_spec

__all__ = ["flash_decode_attn"]

_EMPTY_SLOT = jnp.iinfo(jnp.int32).max


def _check_budget(bc: int, gp: int, hdp: int, depth: int) -> None:
    # k/v/sp chunks: `depth` manual VMEM slots each; q and out tiles are
    # grid-staged (PIPELINE_BUFFERS copies); LUT single-buffered
    working = depth * (2 * budget.tile_bytes((bc, hdp))
                       + budget.tile_bytes((bc,)))
    working += 2 * budget.PIPELINE_BUFFERS * budget.tile_bytes((gp, hdp))
    working += budget.tile_bytes((256,))
    budget.check_working_set(working)


def flash_decode_attn(
    qf: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    slot_positions: jnp.ndarray,
    pos,
    window: int = 0,
    scheme: str | None = None,
    *,
    floor: float = SOFTMAX_FLOOR,
    spec: KernelSpec | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused single-token attention; same contract as ``decode_attn_ref``.

    qf: [B, KV, G, hd] pre-scaled f32 queries; caches: [B, C, KV, hd];
    slot_positions: [B, C] int32; ``pos`` scalar or [B] / [B, 1].
    ``scheme=None`` is the exact-divide combine (not defaulted from the
    spec: exact softmax is a semantic choice, not a tuning knob).
    ``spec.bk`` overrides the cache chunk size (multiple of 128); left
    unset it resolves via :func:`repro.kernels.spec.resolve_spec` —
    tuning-cache winner, else one lane tile.  ``spec.pipeline.depth``
    sets how many chunk fetches stay in flight.  Depth is schedule-only
    (bit-exact); the chunk size re-chunks the online softmax, keeping
    this family's tight-allclose parity contract vs the reference.
    Returns [B, KV, G, hd] f32.
    """
    ks = as_kernel_spec(spec)
    if interpret is None:
        interpret = ks.interpret
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, kv, g, hd = qf.shape
    c = k_cache.shape[1]
    rows = b * kv
    ks = resolve_spec("flash_attn", (rows, c, g, hd), ks, scheme=scheme)
    bc = ks.bk
    if bc % budget.LANE:
        raise ValueError(f"cache chunk bc={bc} must be a multiple of "
                         f"{budget.LANE} (slot positions ride the lanes)")
    depth = ks.depth
    gp = budget.round_up(g, budget.SUBLANE)
    hdp = budget.round_up(hd, budget.LANE)
    cpad = budget.round_up(c, bc)
    _check_budget(bc, gp, hdp, depth)
    q2 = jnp.pad(qf.astype(jnp.float32).reshape(rows, g, hd),
                 ((0, 0), (0, gp - g), (0, hdp - hd)))
    def cache_rows(cache):
        c2 = cache.transpose(0, 2, 1, 3).reshape(rows, c, hd)
        return jnp.pad(c2.astype(jnp.float32),
                       ((0, 0), (0, cpad - c), (0, hdp - hd)))
    k2 = cache_rows(k_cache)
    v2 = cache_rows(v_cache)
    sp2 = jnp.pad(
        jnp.repeat(slot_positions.astype(jnp.int32), kv, axis=0),
        ((0, 0), (0, cpad - c)), constant_values=_EMPTY_SLOT)
    posq = jnp.broadcast_to(canon_posq(pos).astype(jnp.int32), (b, 1))
    posq2 = jnp.repeat(posq, kv, axis=0)
    dlut = fa.div_lut_device(scheme) if scheme else None
    out = flash_decode_pallas(q2, k2, v2, sp2, posq2, dlut, bc=bc,
                              depth=depth, window=window, floor=float(floor),
                              interpret=interpret)
    return out[:, :g, :hd].reshape(b, kv, g, hd)
