from repro.kernels.flash_attn.ops import flash_decode_attn

__all__ = ["flash_decode_attn"]
