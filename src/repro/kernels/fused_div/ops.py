"""jit'd public wrappers for the fused divider kernels (pad + dispatch).

The wrappers own shape plumbing only: collapse leading dims to rows, pad
rows to the block grid and lanes to a multiple of 128, dispatch, slice.
Padding values are chosen so the pad lanes stay numerically inert (zeros
in the reduced numerator, ones in elementwise denominators) and the pad
rows cannot trap (0/floor = 0, sqrt(eps) > 0); everything padded is
sliced off before return.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.kernels import budget
from repro.kernels.fused_div import ref
from repro.kernels.fused_div.fused_div import (
    div_pallas,
    div_rowbcast_pallas,
    rms_div_pallas,
    softmax_div_pallas,
)

__all__ = ["fused_softmax_div", "fused_rms_div", "fused_elementwise_div"]


def _pick_bm(m: int, npad: int) -> int:
    """Rows per grid step: >= the f32 sublane tile, capped so the in/out
    slabs stay under ``budget.ROW_SLAB_BYTES`` each — the same constants
    the static kernel auditor (RPD005) enforces."""
    rows = budget.round_up(m, budget.SUBLANE)
    bm = max(budget.SUBLANE,
             min(budget.MAX_BM, budget.slab_rows(npad), rows))
    # in + out slabs double-buffered, LUT single-buffered
    budget.check_working_set(
        2 * budget.PIPELINE_BUFFERS * budget.tile_bytes((bm, npad))
        + budget.tile_bytes((256,)))
    return bm


def _default_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _as_rows(x: jnp.ndarray):
    """[..., n] -> padded [M_pad, n_pad] f32 + the unpad geometry."""
    lead, n = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, n).astype(jnp.float32)
    m = x2.shape[0]
    npad = ref.padded_width(n)
    bm = _pick_bm(m, npad)
    mp = -(-m // bm) * bm
    xp = jnp.pad(x2, ((0, mp - m), (0, npad - n)))
    return xp, bm, m, n, lead


def fused_softmax_div(e: jnp.ndarray, scheme: str, *,
                      floor: float = ref.SOFTMAX_FLOOR,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Softmax combine: e / max(sum(e, -1), floor), fused in one pass."""
    interpret = _default_interpret(interpret)
    lut = fa.div_lut_device(scheme)
    ep, bm, m, n, lead = _as_rows(e)
    out = softmax_div_pallas(ep, lut, floor=float(floor), bm=bm,
                             interpret=interpret)
    return out[:m, :n].reshape(*lead, n).astype(e.dtype)


def fused_rms_div(x: jnp.ndarray, eps: float, scheme: str, *,
                  interpret: bool | None = None) -> jnp.ndarray:
    """RMS normalize: x / sqrt(mean(x^2, -1) + eps), fused in one pass."""
    interpret = _default_interpret(interpret)
    lut = fa.div_lut_device(scheme)
    xp, bm, m, n, lead = _as_rows(x)
    out = rms_div_pallas(xp, lut, n=n, eps=float(eps), bm=bm,
                         interpret=interpret)
    return out[:m, :n].reshape(*lead, n).astype(x.dtype)


def fused_elementwise_div(a: jnp.ndarray, b: jnp.ndarray, scheme: str, *,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Elementwise RAPID a/b (broadcasting ok); output dtype follows a.

    The one-denominator-per-row shape (``b`` scalar or trailing dim 1,
    as the online-softmax combine divides ``acc`` by ``l[..., None]``)
    dispatches to a row-broadcast kernel: ``b`` stays a vector and the
    lane broadcast happens in VMEM instead of materialising an a-sized
    denominator tensor in HBM.
    """
    interpret = _default_interpret(interpret)
    lut = fa.div_lut_device(scheme)
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    orig = a.dtype
    out_shape = jnp.broadcast_shapes(a.shape, b.shape)
    rowbcast = (out_shape == a.shape and a.ndim >= 1
                and (b.ndim == 0 or b.shape[-1] == 1))
    if rowbcast:
        ap, bm, m, n, lead = _as_rows(a)
        # [M_pad, 1] column: the denominator's row count lives on the
        # sublane axis where bm-alignment holds (see _div_rowbcast_kernel)
        bv = jnp.broadcast_to(b, (*a.shape[:-1], 1)).reshape(-1, 1)
        bv = jnp.pad(bv.astype(jnp.float32), ((0, ap.shape[0] - m), (0, 0)),
                     constant_values=1.0)
        out = div_rowbcast_pallas(ap, bv, lut, bm=bm, interpret=interpret)
        return out[:m, :n].reshape(*lead, n).astype(orig)
    a, b = jnp.broadcast_arrays(a, b)
    shape = a.shape
    br, bc = 8, ref.LANE
    af = a.reshape(-1).astype(jnp.float32)
    bf = b.reshape(-1).astype(jnp.float32)
    pad = (-af.size) % (br * bc)
    af = jnp.pad(af, (0, pad)).reshape(-1, bc)
    bf = jnp.pad(bf, (0, pad), constant_values=1.0).reshape(-1, bc)
    out = div_pallas(af, bf, lut, block=(br, bc), interpret=interpret)
    return out.reshape(-1)[: a.size].reshape(shape).astype(orig)
