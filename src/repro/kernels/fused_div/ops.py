"""jit'd public wrappers for the fused divider kernels (pad + dispatch).

The wrappers own shape plumbing only: collapse leading dims to rows, pad
rows to the block grid and lanes to a multiple of 128, dispatch, slice.
Padding values are chosen so the pad lanes stay numerically inert (zeros
in the reduced numerator, ones in elementwise denominators) and the pad
rows cannot trap (0/floor = 0, sqrt(eps) > 0); everything padded is
sliced off before return.

Every wrapper accepts the shared :class:`repro.kernels.spec.KernelSpec`
(``spec=``); geometry left unset resolves through
:func:`repro.kernels.spec.resolve_spec` — explicit ``bm``/depth >
committed tuning-cache winner (``TUNE_baseline.json``) > the slab-row
heuristic.  ``spec.pipeline.depth`` selects the formulation — depth 1
the legacy grid loop, depth >= 2 (the default,
``budget.PIPELINE_BUFFERS``) the software-pipelined slab loop with
explicit async-copy staging.  Both are bit-exact against each other and
the jnp reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.kernels import budget
from repro.kernels.fused_div import ref
from repro.kernels.fused_div.fused_div import (
    div_pallas,
    div_rowbcast_pallas,
    rms_div_pallas,
    softmax_div_pallas,
)
from repro.kernels.spec import KernelSpec, as_kernel_spec, resolve_spec

__all__ = ["fused_softmax_div", "fused_rms_div", "fused_elementwise_div"]


def _check_budget(bm: int, npad: int, depth: int) -> None:
    # in + out slabs: grid double-buffered at depth 1, `depth` manual
    # VMEM scratch slots per side at depth >= 2; LUT single-buffered
    buffers = depth if depth >= 2 else budget.PIPELINE_BUFFERS
    budget.check_working_set(
        2 * buffers * budget.tile_bytes((bm, npad))
        + budget.tile_bytes((256,)))


def _resolve(spec, interpret):
    ks = as_kernel_spec(spec)
    if interpret is None:
        interpret = ks.interpret
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return ks, interpret


def _as_rows(x: jnp.ndarray, ks: KernelSpec, family: str,
             scheme: str | None):
    """[..., n] -> padded [M_pad, n_pad] f32 + the resolved spec and
    unpad geometry.  ``family`` keys the resolve_spec tuning-cache
    lookup (explicit ``bm``/depth > cache > slab-row heuristic); the
    budget check applies to the winner regardless of source."""
    lead, n = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, n).astype(jnp.float32)
    m = x2.shape[0]
    npad = ref.padded_width(n)
    ks = resolve_spec(family, (m, n), ks, scheme=scheme)
    bm = ks.bm
    _check_budget(bm, npad, ks.depth)
    mp = -(-m // bm) * bm
    xp = jnp.pad(x2, ((0, mp - m), (0, npad - n)))
    return xp, ks, m, n, lead


def fused_softmax_div(e: jnp.ndarray, scheme: str | None = None, *,
                      floor: float = ref.SOFTMAX_FLOOR,
                      spec: KernelSpec | None = None,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Softmax combine: e / max(sum(e, -1), floor), fused in one pass."""
    ks, interpret = _resolve(spec, interpret)
    scheme = scheme or ks.scheme or "rapid9"
    lut = fa.div_lut_device(scheme)
    ep, ks, m, n, lead = _as_rows(e, ks, "fused_softmax", scheme)
    out = softmax_div_pallas(ep, lut, floor=float(floor), bm=ks.bm,
                             depth=ks.depth, interpret=interpret)
    return out[:m, :n].reshape(*lead, n).astype(e.dtype)


def fused_rms_div(x: jnp.ndarray, eps: float, scheme: str | None = None, *,
                  spec: KernelSpec | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """RMS normalize: x / sqrt(mean(x^2, -1) + eps), fused in one pass."""
    ks, interpret = _resolve(spec, interpret)
    scheme = scheme or ks.scheme or "rapid9"
    lut = fa.div_lut_device(scheme)
    xp, ks, m, n, lead = _as_rows(x, ks, "fused_rms", scheme)
    out = rms_div_pallas(xp, lut, n=n, eps=float(eps), bm=ks.bm,
                         depth=ks.depth, interpret=interpret)
    return out[:m, :n].reshape(*lead, n).astype(x.dtype)


def fused_elementwise_div(a: jnp.ndarray, b: jnp.ndarray,
                          scheme: str | None = None, *,
                          spec: KernelSpec | None = None,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Elementwise RAPID a/b (broadcasting ok); output dtype follows a.

    The one-denominator-per-row shape (``b`` scalar or trailing dim 1,
    as the online-softmax combine divides ``acc`` by ``l[..., None]``)
    dispatches to a row-broadcast kernel: ``b`` stays a vector and the
    lane broadcast happens in VMEM instead of materialising an a-sized
    denominator tensor in HBM.  The tiled fallback for fully general
    broadcasts has no slab structure to pipeline and always runs the
    grid formulation.
    """
    ks, interpret = _resolve(spec, interpret)
    scheme = scheme or ks.scheme or "rapid9"
    lut = fa.div_lut_device(scheme)
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    orig = a.dtype
    out_shape = jnp.broadcast_shapes(a.shape, b.shape)
    rowbcast = (out_shape == a.shape and a.ndim >= 1
                and (b.ndim == 0 or b.shape[-1] == 1))
    if rowbcast:
        ap, ks, m, n, lead = _as_rows(a, ks, "fused_div_rowbcast", scheme)
        # [M_pad, 1] column: the denominator's row count lives on the
        # sublane axis where bm-alignment holds (see _div_rowbcast_kernel)
        bv = jnp.broadcast_to(b, (*a.shape[:-1], 1)).reshape(-1, 1)
        bv = jnp.pad(bv.astype(jnp.float32), ((0, ap.shape[0] - m), (0, 0)),
                     constant_values=1.0)
        out = div_rowbcast_pallas(ap, bv, lut, bm=ks.bm, depth=ks.depth,
                                  interpret=interpret)
        return out[:m, :n].reshape(*lead, n).astype(orig)
    a, b = jnp.broadcast_arrays(a, b)
    shape = a.shape
    br, bc = 8, ref.LANE
    af = a.reshape(-1).astype(jnp.float32)
    bf = b.reshape(-1).astype(jnp.float32)
    pad = (-af.size) % (br * bc)
    af = jnp.pad(af, (0, pad)).reshape(-1, bc)
    bf = jnp.pad(bf, (0, pad), constant_values=1.0).reshape(-1, bc)
    out = div_pallas(af, bf, lut, block=(br, bc), interpret=interpret)
    return out.reshape(-1)[: a.size].reshape(shape).astype(orig)
