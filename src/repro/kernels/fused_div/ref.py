"""Canonical semantics for the fused divider ops (the jnp oracle).

Every function here is used *verbatim* by both the jnp backend and the
Pallas kernel bodies, so the two execution paths agree bit-for-bit by
construction — the same guarantee the log_matmul kernel gets from
sharing ``float_approx.log_mul_f32``.

The one subtlety is the denominator reduction.  XLA's reduce picks its
partial-sum grouping from the *shape* of the reduced operand, so summing
a row of ``n`` elements and summing the same row zero-padded to ``n'``
can differ in the last ulp.  The kernel necessarily reduces the 128-lane
-padded row it holds in VMEM; the canonical semantics therefore *define*
the denominator as the reduction over the lane-padded row (appended
zeros are mathematically inert — every input row is padded with exact
zeros), and the jnp oracle pads the same way.  Empirically the grouping
depends only on the padded width, not on the number of rows in the
operand, which is what lets a [bm, n_pad] kernel tile match a [M, n_pad]
oracle reduction.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import float_approx as fa

__all__ = [
    "LANE",
    "SOFTMAX_FLOOR",
    "padded_width",
    "pad_lanes",
    "softmax_denom",
    "rms_denom",
    "softmax_div_ref",
    "rms_div_ref",
]

# TPU vector lane count: the last dim of every kernel block is padded to
# a multiple of this, and the canonical denominator reduction runs over
# the padded row.
LANE = 128

# Denominator floor for the softmax combine: keeps fully-masked rows
# (sum of exp-weights == 0) from dividing by zero.  Matches the floor
# the attention layers applied before the op was fused.
SOFTMAX_FLOOR = 1e-20


def padded_width(n: int) -> int:
    """Last-dim width after padding to a multiple of LANE."""
    return -(-n // LANE) * LANE


def pad_lanes(x: jnp.ndarray) -> jnp.ndarray:
    """Zero-pad the last dim to a multiple of LANE (identity if aligned)."""
    n = x.shape[-1]
    pad = padded_width(n) - n
    if not pad:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def softmax_denom(e_padded: jnp.ndarray, floor: float) -> jnp.ndarray:
    """Row-sum of exp-weights with a floor; ``e_padded`` is lane-padded."""
    return jnp.maximum(jnp.sum(e_padded, axis=-1, keepdims=True),
                       jnp.float32(floor))


def rms_denom(x_padded: jnp.ndarray, n: int, eps: float) -> jnp.ndarray:
    """sqrt(mean(x^2) + eps) where the mean is over the *real* width n.

    Canonicalised as ``sqrt((ss + n*eps) * (1/n))`` — algebraically the
    same, but every op is immune to the compilation-context rewrites
    that break bit-parity between eager jnp and a jitted pallas body:
    a divide-by-constant gets strength-reduced inconsistently, and a
    ``ss*(1/n) + eps`` chain FMA-contracts inside the pallas_call (the
    same instability the fused-epilogue notes document for gelu's tanh
    form).  ``(add-const) * mul-const`` followed by sqrt has no
    contractible pattern; the constants are folded once in python so
    both contexts see identical f32 literals.
    """
    ss = jnp.sum(x_padded * x_padded, axis=-1, keepdims=True)
    arg = (ss + jnp.float32(n * eps)) * jnp.float32(1.0 / n)
    return jnp.sqrt(arg)


def softmax_div_ref(e: jnp.ndarray, lut: jnp.ndarray,
                    floor: float = SOFTMAX_FLOOR) -> jnp.ndarray:
    """exp-weights / row-sum through the RAPID divider.  f32 in/out."""
    denom = softmax_denom(pad_lanes(e), floor)
    return fa.log_div_f32(e, denom, lut)


def rms_div_ref(x: jnp.ndarray, lut: jnp.ndarray, eps: float) -> jnp.ndarray:
    """x / sqrt(mean(x^2, last axis) + eps) via the RAPID divider. f32."""
    denom = rms_denom(pad_lanes(x), x.shape[-1], eps)
    return fa.log_div_f32(x, denom, lut)
