"""Pallas TPU kernels: fused RAPID divider passes.

Three kernels, all pure VPU (int32 add/sub + 256-entry coefficient
gather — the same per-element cost as the log_matmul products):

  * ``softmax_div_pallas`` — one grid step holds a [bm, n_pad] slab of
    exp-weights in VMEM, reduces the row-sum, floors it, and applies the
    logarithmic divide to the resident slab.  The denominator and the
    un-divided numerator never exist in HBM.
  * ``rms_div_pallas``     — same shape, denominator is
    sqrt(mean(x^2) + eps) over the real (unpadded) row width.
  * ``div_pallas``         — elementwise a/b on pre-broadcast operands
    (the online-softmax combine, whose denominator comes from a scan).

The kernel bodies call the *same* jnp expressions as the jnp backend
(`ref.softmax_denom` / `ref.rms_denom` / `float_approx.log_div_f32`), so
jnp vs pallas-interpret parity is bit-for-bit by construction; the
grid rows are independent ("parallel" semantics, no K accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import float_approx as fa
from repro.kernels.fused_div import ref

__all__ = ["softmax_div_pallas", "rms_div_pallas", "div_pallas",
           "div_rowbcast_pallas"]


def _softmax_kernel(e_ref, lut_ref, o_ref, *, floor: float):
    e = e_ref[...]
    denom = ref.softmax_denom(e, floor)
    o_ref[...] = fa.log_div_f32(e, denom, lut_ref[...])


def _rms_kernel(x_ref, lut_ref, o_ref, *, n: int, eps: float):
    x = x_ref[...]
    denom = ref.rms_denom(x, n, eps)
    o_ref[...] = fa.log_div_f32(x, denom, lut_ref[...])


def _div_kernel(a_ref, b_ref, lut_ref, o_ref):
    o_ref[...] = fa.log_div_f32(a_ref[...], b_ref[...], lut_ref[...])


def _div_rowbcast_kernel(a_ref, b_ref, lut_ref, o_ref):
    # b is one denominator per row as a [bm, 1] column block (a 1-D
    # (bm,) block puts bm on the lane axis, where it is misaligned for
    # any bm that is neither %128 nor the whole row count — RPD006),
    # broadcast over the lanes in VMEM: the [M, N] / [M, 1] shape of the
    # online-softmax combine without materialising the broadcast in HBM
    o_ref[...] = fa.log_div_f32(a_ref[...], b_ref[...], lut_ref[...])


def _rowwise_call(kernel, x, lut, bm: int, interpret: bool):
    """Shared pallas_call plumbing for the row-fused kernels.

    x: [M, n_pad] f32 with M % bm == 0 and n_pad % LANE == 0; every grid
    step owns bm full rows (the whole reduction axis stays in VMEM).
    """
    m, npad = x.shape
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, npad), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, npad), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel",))
        ) if not interpret else None,
        interpret=interpret,
    )(x, lut)


@functools.partial(jax.jit, static_argnames=("floor", "bm", "interpret"))
def softmax_div_pallas(e, lut, *, floor: float = ref.SOFTMAX_FLOOR,
                       bm: int = 8, interpret: bool = False):
    """e[M, n_pad] -> e / max(rowsum(e), floor) with RAPID divides."""
    return _rowwise_call(functools.partial(_softmax_kernel, floor=floor),
                         e, lut, bm, interpret)


@functools.partial(jax.jit, static_argnames=("n", "eps", "bm", "interpret"))
def rms_div_pallas(x, lut, *, n: int, eps: float, bm: int = 8,
                   interpret: bool = False):
    """x[M, n_pad] -> x / sqrt(mean(x[:, :n]^2) + eps), RAPID divides."""
    return _rowwise_call(functools.partial(_rms_kernel, n=n, eps=eps),
                         x, lut, bm, interpret)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def div_rowbcast_pallas(a, b, lut, *, bm: int = 8, interpret: bool = False):
    """a[M, n_pad] / b[M, 1] with the per-row denominator broadcast in VMEM."""
    m, npad = a.shape
    return pl.pallas_call(
        _div_rowbcast_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, npad), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, npad), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel",))
        ) if not interpret else None,
        interpret=interpret,
    )(a, b, lut)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def div_pallas(a, b, lut, *, block=(8, 128), interpret: bool = False):
    """Elementwise RAPID a/b on f32 [rows, cols] tiles (pre-broadcast)."""
    r, c = a.shape
    br, bc = block
    return pl.pallas_call(
        _div_kernel,
        grid=(r // br, c // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((256,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(a, b, lut)
