"""Pallas TPU kernels: fused RAPID divider passes.

Three kernels, all pure VPU (int32 add/sub + 256-entry coefficient
gather — the same per-element cost as the log_matmul products):

  * ``softmax_div_pallas`` — one grid step holds a [bm, n_pad] slab of
    exp-weights in VMEM, reduces the row-sum, floors it, and applies the
    logarithmic divide to the resident slab.  The denominator and the
    un-divided numerator never exist in HBM.
  * ``rms_div_pallas``     — same shape, denominator is
    sqrt(mean(x^2) + eps) over the real (unpadded) row width.
  * ``div_pallas``         — elementwise a/b on pre-broadcast operands
    (the online-softmax combine, whose denominator comes from a scan).

The kernel bodies call the *same* jnp expressions as the jnp backend
(`ref.softmax_denom` / `ref.rms_denom` / `float_approx.log_div_f32`), so
jnp vs pallas-interpret parity is bit-for-bit by construction; the
grid rows are independent ("parallel" semantics, no K accumulation).

Each row-fused wrapper takes a ``depth`` knob (the ``PipelineSpec``
depth from :mod:`repro.kernels.spec`): depth 1 is the legacy grid
formulation above, depth >= 2 lowers to a software-pipelined body —
grid (1,) with the slab loop inside the kernel, x and out in ANY (HBM)
memory, and ``depth`` VMEM scratch slots per side rotating through
explicit ``make_async_copy`` DMAs.  Slab s+depth-1's fetch and slab
s-depth's writeback are both in flight while slab s computes, the
paper's pipelined-divider schedule.  The per-slab tile expression is
shared verbatim between the two formulations (``_*_tile``), so they
are bit-exact against each other and the jnp reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import float_approx as fa
from repro.kernels.fused_div import ref

__all__ = ["softmax_div_pallas", "rms_div_pallas", "div_pallas",
           "div_rowbcast_pallas"]


def _softmax_tile(e, lut, *, floor: float):
    return fa.log_div_f32(e, ref.softmax_denom(e, floor), lut)


def _rms_tile(x, lut, *, n: int, eps: float):
    return fa.log_div_f32(x, ref.rms_denom(x, n, eps), lut)


def _softmax_kernel(e_ref, lut_ref, o_ref, *, floor: float):
    o_ref[...] = _softmax_tile(e_ref[...], lut_ref[...], floor=floor)


def _rms_kernel(x_ref, lut_ref, o_ref, *, n: int, eps: float):
    o_ref[...] = _rms_tile(x_ref[...], lut_ref[...], n=n, eps=eps)


def _div_kernel(a_ref, b_ref, lut_ref, o_ref):
    o_ref[...] = fa.log_div_f32(a_ref[...], b_ref[...], lut_ref[...])


def _div_rowbcast_kernel(a_ref, b_ref, lut_ref, o_ref):
    # b is one denominator per row as a [bm, 1] column block (a 1-D
    # (bm,) block puts bm on the lane axis, where it is misaligned for
    # any bm that is neither %128 nor the whole row count — RPD006),
    # broadcast over the lanes in VMEM: the [M, N] / [M, 1] shape of the
    # online-softmax combine without materialising the broadcast in HBM
    o_ref[...] = fa.log_div_f32(a_ref[...], b_ref[...], lut_ref[...])


def _rowwise_pipelined_kernel(x_hbm, lut_ref, *rest, tile_fn, bm: int,
                              nslabs: int, depth: int, has_b: bool):
    """Software-pipelined slab loop: in-DMA ahead, out-DMA behind.

    Slab s's input slot (s % depth) is also its output slot; before
    computing into it we wait slab s-depth's writeback (same slot), so
    every slot is quiescent when reused.  Warm-up and drain bounds are
    static (nslabs is a trace-time constant), so every DMA is started
    exactly once and waited exactly once.
    """
    refs = list(rest)
    b_ref = refs.pop(0) if has_b else None
    o_hbm, x_scr, o_scr, x_sem, o_sem = refs

    def in_dma(slot, s):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(s * bm, bm), :], x_scr.at[slot], x_sem.at[slot])

    def out_dma(slot, s):
        return pltpu.make_async_copy(
            o_scr.at[slot], o_hbm.at[pl.ds(s * bm, bm), :], o_sem.at[slot])

    for d in range(min(depth - 1, nslabs)):
        in_dma(d % depth, d).start()
    lut = lut_ref[...]

    def step(s, carry):
        slot = jax.lax.rem(s, depth)
        nxt = s + depth - 1

        @pl.when(nxt < nslabs)
        def _prefetch():
            in_dma(jax.lax.rem(nxt, depth), nxt).start()

        in_dma(slot, s).wait()

        @pl.when(s >= depth)
        def _retire():
            out_dma(slot, s - depth).wait()

        x_slab = x_scr[slot]
        if has_b:
            o_scr[slot] = tile_fn(x_slab, b_ref[pl.ds(s * bm, bm), :], lut)
        else:
            o_scr[slot] = tile_fn(x_slab, lut)
        out_dma(slot, s).start()
        return carry

    jax.lax.fori_loop(0, nslabs, step, 0)
    for s in range(max(0, nslabs - depth), nslabs):
        out_dma(s % depth, s).wait()


def _rowwise_pipelined_call(tile_fn, x, lut, bm: int, depth: int,
                            interpret: bool, b=None):
    """pallas_call plumbing for the depth>=2 row-fused formulation."""
    m, npad = x.shape
    nslabs = m // bm
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    in_specs = [any_spec, pl.BlockSpec((256,), lambda i: (0,))]
    operands = [x, lut]
    if b is not None:
        # the whole [M, 1] denominator column stays resident in VMEM
        # (4 bytes/row); slabs are sliced in-kernel
        in_specs.append(pl.BlockSpec((m, 1), lambda i: (0, 0)))
        operands.append(b)
    return pl.pallas_call(
        functools.partial(_rowwise_pipelined_kernel, tile_fn=tile_fn,
                          bm=bm, nslabs=nslabs, depth=depth,
                          has_b=b is not None),
        grid=(1,),
        in_specs=in_specs,
        out_specs=any_spec,
        out_shape=jax.ShapeDtypeStruct((m, npad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((depth, bm, npad), jnp.float32),
            pltpu.VMEM((depth, bm, npad), jnp.float32),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary",))
        ) if not interpret else None,
        interpret=interpret,
    )(*operands)


def _rowwise_call(kernel, x, lut, bm: int, interpret: bool):
    """Shared pallas_call plumbing for the row-fused kernels.

    x: [M, n_pad] f32 with M % bm == 0 and n_pad % LANE == 0; every grid
    step owns bm full rows (the whole reduction axis stays in VMEM).
    """
    m, npad = x.shape
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, npad), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, npad), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel",))
        ) if not interpret else None,
        interpret=interpret,
    )(x, lut)


@functools.partial(jax.jit,
                   static_argnames=("floor", "bm", "depth", "interpret"))
def softmax_div_pallas(e, lut, *, floor: float = ref.SOFTMAX_FLOOR,
                       bm: int = 8, depth: int = 1,
                       interpret: bool = False):
    """e[M, n_pad] -> e / max(rowsum(e), floor) with RAPID divides."""
    if depth >= 2:
        return _rowwise_pipelined_call(
            functools.partial(_softmax_tile, floor=floor),
            e, lut, bm, depth, interpret)
    return _rowwise_call(functools.partial(_softmax_kernel, floor=floor),
                         e, lut, bm, interpret)


@functools.partial(jax.jit,
                   static_argnames=("n", "eps", "bm", "depth", "interpret"))
def rms_div_pallas(x, lut, *, n: int, eps: float, bm: int = 8,
                   depth: int = 1, interpret: bool = False):
    """x[M, n_pad] -> x / sqrt(mean(x[:, :n]^2) + eps), RAPID divides."""
    if depth >= 2:
        return _rowwise_pipelined_call(
            functools.partial(_rms_tile, n=n, eps=eps),
            x, lut, bm, depth, interpret)
    return _rowwise_call(functools.partial(_rms_kernel, n=n, eps=eps),
                         x, lut, bm, interpret)


@functools.partial(jax.jit, static_argnames=("bm", "depth", "interpret"))
def div_rowbcast_pallas(a, b, lut, *, bm: int = 8, depth: int = 1,
                        interpret: bool = False):
    """a[M, n_pad] / b[M, 1] with the per-row denominator broadcast in VMEM."""
    m, npad = a.shape
    if depth >= 2:
        return _rowwise_pipelined_call(
            fa.log_div_f32, a, lut, bm, depth, interpret, b=b)
    return pl.pallas_call(
        _div_rowbcast_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, npad), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, npad), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel",))
        ) if not interpret else None,
        interpret=interpret,
    )(a, b, lut)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def div_pallas(a, b, lut, *, block=(8, 128), interpret: bool = False):
    """Elementwise RAPID a/b on f32 [rows, cols] tiles (pre-broadcast)."""
    r, c = a.shape
    br, bc = block
    return pl.pallas_call(
        _div_kernel,
        grid=(r // br, c // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((256,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(a, b, lut)
