"""Fused RAPID divider kernels (softmax combine, rms normalize, elementwise).

The paper's headline wins come from "division-included applications";
SIMDive (the same group's predecessor) shows the multiplier/divider pair
only pays off when the divide is *fused* into the surrounding datapath.
This package is that fusion for TPU: one VMEM-resident pass computes the
denominator reduction (softmax row-sum / rms mean-of-squares) and the
RAPID logarithmic divide, so neither the denominator nor the un-divided
numerator ever round-trips HBM.

Layout follows the sibling kernels: ``ref.py`` holds the canonical jnp
semantics (shared verbatim with the kernel bodies for bit-parity),
``fused_div.py`` the Pallas kernels, ``ops.py`` the padding/dispatch
wrappers the backend registry calls.
"""
