"""Shared VMEM/tiling budget — one source of truth for kernels and audit.

Every number the Pallas kernels' block-size heuristics rely on lives
here, so the static kernel auditor (``repro.analysis.kernel_audit``)
checks the *same* constants the kernels use instead of re-deriving
"~1 MiB" comments.  The heuristic fallbacks live in one place —
``kernels/spec.py::resolve_spec`` (explicit spec field > tuning-cache
winner > heuristic) — and import from this module, as does the
autotuner's candidate legality filter (``kernels/autotune.py``); the
auditor
fails any captured ``pallas_call`` whose per-grid-step working set
(double-buffered operand tiles + single-buffered LUT constants)
exceeds :func:`vmem_budget`.

All limits assume 32-bit element types (f32 / int32 / uint32), which is
every dtype the kernel families move today.
"""
from __future__ import annotations

__all__ = [
    "LANE",
    "SUBLANE",
    "ELEM_BYTES",
    "VMEM_BUDGET_BYTES",
    "PIPELINE_BUFFERS",
    "ROW_SLAB_BYTES",
    "W_SLAB_BYTES",
    "MAX_BM",
    "MAX_BN",
    "MAX_BK",
    "round_up",
    "slab_rows",
    "slab_depth",
    "tile_bytes",
    "check_working_set",
]

# TPU vector-register tile for 32-bit types: 8 sublanes x 128 lanes.
LANE = 128
SUBLANE = 8
ELEM_BYTES = 4

# Per-core VMEM capacity the kernels budget against.  TPU cores carry
# 16 MiB of VMEM; Mosaic's grid pipeline double-buffers every
# grid-varying operand, so the *effective* budget per grid step is
# working_set * PIPELINE_BUFFERS <= VMEM_BUDGET_BYTES.  The "cpu" entry
# bounds the interpreter path identically so geometry never forks per
# platform.
VMEM_BUDGET_BYTES = {"tpu": 16 * 2**20, "cpu": 16 * 2**20}
PIPELINE_BUFFERS = 2

# Per-operand slab targets used by the block-size heuristics: row slabs
# (x / out / pre-norm / residual tiles of a norm-epilogue matmul) stay
# under 1 MiB of f32 each, the weight slab under 2 MiB.  With four row
# slabs + one weight slab double-buffered that is ~12 MiB worst case,
# inside the 16 MiB budget with headroom for LUTs and semaphores.
ROW_SLAB_BYTES = 1 << 20
W_SLAB_BYTES = 1 << 21

# Hard caps on matmul block dims (multiples of the minimum tile).
MAX_BM = 256
MAX_BN = 256
MAX_BK = 512


def round_up(v: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= ``v``."""
    return -(-v // mult) * mult


def slab_rows(npad: int, slab_bytes: int = ROW_SLAB_BYTES) -> int:
    """Largest sublane-aligned row count with rows*npad f32 <= slab."""
    return max(SUBLANE, (slab_bytes // ELEM_BYTES // npad) // SUBLANE * SUBLANE)


def slab_depth(npad: int, slab_bytes: int = W_SLAB_BYTES) -> int:
    """Largest lane-aligned K depth with bk*npad f32 <= slab."""
    return max(LANE, (slab_bytes // ELEM_BYTES // npad) // LANE * LANE)


def tile_bytes(block_shape, elem_bytes: int = ELEM_BYTES) -> int:
    """Bytes of one VMEM tile for a BlockSpec block shape."""
    size = 1
    for d in block_shape:
        size *= int(d)
    return size * elem_bytes


def vmem_budget(platform: str = "tpu") -> int:
    """Per-core VMEM budget in bytes for ``platform``."""
    return VMEM_BUDGET_BYTES.get(platform, min(VMEM_BUDGET_BYTES.values()))


def check_working_set(working_set_bytes: int, platform: str = "tpu") -> None:
    """Raise if a kernel's per-grid-step working set blows the budget.

    Called by the family wrappers on the *resolved* block choice —
    explicit spec field, tuning-cache winner, or heuristic alike — so an
    oversized spec fails at call time with the same constant the static
    auditor enforces.
    """
    budget = vmem_budget(platform)
    if working_set_bytes > budget:
        raise ValueError(
            f"kernel working set {working_set_bytes} B exceeds the "
            f"{platform} VMEM budget {budget} B "
            "(repro.kernels.budget.VMEM_BUDGET_BYTES)"
        )
