"""Shared kernel-call contract: one spec from call site to auditor.

Every kernel-family public wrapper (``log_matmul``, the ``fused_*_div``
trio, ``rapid_mul``/``rapid_div``, ``flash_decode_attn``) accepts the
same :class:`KernelSpec`, and ``core/backend.py``'s dispatchers and the
kernel auditor's capture drivers pass the same object through — block
geometry, pipeline depth, interpret mode, scheme and epilogue are one
hashable value instead of a family-specific kwarg soup.

Both dataclasses are frozen (hashable), so a spec can ride ``jax.jit``
static arguments and ``functools.partial`` keywords unchanged.

Pipeline semantics (:class:`PipelineSpec`):

  * ``depth == 1`` — the legacy grid formulation: one tile per grid
    step, HBM->VMEM staging left to Mosaic's hardware-managed grid
    pipeline (which itself double-buffers grid-varying operands).
  * ``depth >= 2`` — explicit software pipelining: the wrapper lowers
    to the manual async-copy kernel, which keeps operands in ANY
    (HBM) memory and rotates ``depth`` VMEM scratch slots per operand,
    starting the DMA for tile ``t+depth-1`` before computing tile
    ``t`` (the paper's pipelined-unit schedule, one slot per stage).
  * ``depth is None`` — *deferred*: :func:`resolve_spec` fills it from
    the tuning cache, else :data:`repro.kernels.budget.PIPELINE_BUFFERS`
    — so the software-pipelined path stays the production default and
    an explicitly requested depth is distinguishable from the default.

Spec resolution (:func:`resolve_spec`) is the single choke point every
wrapper and ``core/backend.py`` dispatcher goes through.  Precedence,
per field:

  1. an explicitly-set :class:`KernelSpec` field (the caller's choice);
  2. a tuning-cache hit — the committed, device-measured winners in
     ``TUNE_baseline.json`` (``repro.kernels.autotune``), keyed by
     ``(family, shape class, scheme, epilogue kind, platform)``;
  3. the budget-derived heuristic fallback (off-TPU / cache miss) —
     the former ``log_matmul/ops.py::_pick_blocks`` and
     ``fused_div/ops.py::_pick_bm``, now private to this module.

Norm-epilogue matmuls additionally force whole lane-padded rows per
output tile (canonical denominator semantics) and rebalance ``bm``/
``bk`` to keep the VMEM working set bounded; that is a *hard geometry
constraint*, applied after resolution to every source — explicit,
cached, or heuristic — exactly as the wrapper always did.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple, Union

from repro.kernels import budget

__all__ = [
    "PipelineSpec",
    "KernelSpec",
    "as_kernel_spec",
    "resolve_spec",
    "epilogue_kind",
    "RESOLVED_FAMILIES",
]

#: kernel families :func:`resolve_spec` knows how to resolve, with the
#: ``shapes`` tuple each expects (all python ints, pre-padding):
#:   log_matmul          (m, n, k)
#:   fused_softmax       (rows, n)
#:   fused_rms           (rows, n)
#:   fused_div_rowbcast  (rows, n)
#:   flash_attn          (rows, cache_slots, group, head_dim)
RESOLVED_FAMILIES = (
    "log_matmul", "fused_softmax", "fused_rms", "fused_div_rowbcast",
    "flash_attn",
)

_ROW_FAMILIES = ("fused_softmax", "fused_rms", "fused_div_rowbcast")


@dataclass(frozen=True)
class PipelineSpec:
    """How deep the software pipeline stages HBM->VMEM tile copies."""

    #: number of VMEM scratch slots per pipelined operand; 1 disables
    #: the manual pipeline (hardware grid double-buffering only); None
    #: defers to resolve_spec (tuning cache, else PIPELINE_BUFFERS)
    depth: Optional[int] = None

    def __post_init__(self):
        if self.depth is not None and not 1 <= int(self.depth) <= 8:
            raise ValueError(
                f"pipeline depth {self.depth} outside [1, 8] "
                "(deeper than 8 slots has no VMEM headroom)")


@dataclass(frozen=True)
class KernelSpec:
    """Uniform kernel-call spec shared by every kernel family.

    ``bm``/``bn``/``bk`` are rows / lanes / contraction depth per tile
    (``bk`` doubles as the cache chunk size for ``flash_decode_attn``).
    A ``None`` field defers to :func:`resolve_spec` — tuning cache hit,
    else the family's budget-derived heuristic; families without a K
    dimension (the fused dividers, the integer units) ignore ``bk``.
    ``interpret=None`` keeps the per-wrapper CPU autodetect.
    """

    bm: Optional[int] = None
    bn: Optional[int] = None
    bk: Optional[int] = None
    pipeline: PipelineSpec = field(default_factory=PipelineSpec)
    interpret: Optional[bool] = None
    scheme: Optional[str] = None
    epilogue: Optional[object] = None  # repro.core.backend.Epilogue

    @property
    def depth(self) -> int:
        """Concrete pipeline depth (deferred -> PIPELINE_BUFFERS)."""
        d = self.pipeline.depth
        return budget.PIPELINE_BUFFERS if d is None else int(d)

    def with_depth(self, depth: Optional[int]) -> "KernelSpec":
        return replace(self, pipeline=PipelineSpec(depth=depth))


def as_kernel_spec(spec: Union["KernelSpec", None]) -> KernelSpec:
    """Canonicalize a wrapper's ``spec=`` argument (None -> defaults).

    The one-release positional ``blocks=(bm, bn, bk)`` tuple shim is
    gone: tuples/lists raise ``TypeError`` naming the replacement.
    """
    if spec is None:
        return KernelSpec()
    if isinstance(spec, KernelSpec):
        return spec
    if isinstance(spec, (tuple, list)):
        raise TypeError(
            "positional blocks tuples were removed; pass "
            "spec=KernelSpec(bm=..., bn=..., bk=...) instead")
    raise TypeError(f"spec must be a KernelSpec or None, got {spec!r}")


def epilogue_kind(epilogue: Optional[object]) -> str:
    """Canonical epilogue label for tuning-cache keys.

    Collapses an ``Epilogue`` spec (duck-typed: this module must not
    import ``core.backend``) to the coarse classes that change kernel
    geometry: ``plain`` (identity), ``act`` (elementwise-only stages),
    ``rms`` / ``softmax`` (norm stages, whole-row output tiles), with
    ``+pre`` appended when the pre-norm value is kept (an extra row
    slab in VMEM).
    """
    if epilogue is None:
        return "plain"
    norm = getattr(epilogue, "norm", None)
    if norm is None:
        act = getattr(epilogue, "activation", None)
        return "plain" if act is None else "act"
    kind = str(norm)
    if getattr(epilogue, "keep_prenorm", False):
        kind += "+pre"
    return kind


# --------------------------------------------------------------------------
# heuristic fallbacks (formerly log_matmul/ops.py::_pick_blocks and
# fused_div/ops.py::_pick_bm — private to the resolve_spec choke point)
# --------------------------------------------------------------------------

def _default_matmul_blocks(m: int, n: int, k: int) -> Tuple[int, int, int]:
    """Hardware-aligned matmul blocks that fit the VMEM budget.

    Every block is clamped to the problem size *rounded up to the
    minimum tile* (``budget.SUBLANE`` x ``budget.LANE`` for f32):
    degenerate dims smaller than a tile used to leak through as
    unaligned block shapes, and a K dim between 128 and 512 that was
    not a multiple of the unroll factor silently dropped its tail
    elements (``bk // unroll`` truncated — the smoke-mode shapes
    exposed this).  Keeping bk a multiple of 128 keeps it a multiple of
    any unroll <= 8.  All caps come from :mod:`repro.kernels.budget` —
    the same constants the static kernel auditor (RPD005/RPD006)
    enforces over the captured BlockSpecs.
    """
    bm = min(budget.MAX_BM, budget.round_up(m, budget.SUBLANE))
    bn = min(budget.MAX_BN, budget.round_up(n, budget.LANE))
    bk = min(budget.MAX_BK, budget.round_up(k, budget.LANE))
    return bm, bn, bk


def _default_row_bm(m: int, npad: int) -> int:
    """Rows per fused-divider slab: >= the f32 sublane tile, capped so
    the in/out slabs stay under ``budget.ROW_SLAB_BYTES`` each — the
    same constants the static kernel auditor (RPD005) enforces."""
    rows = budget.round_up(m, budget.SUBLANE)
    return max(budget.SUBLANE,
               min(budget.MAX_BM, budget.slab_rows(npad), rows))


def _rebalance_norm_matmul(bm: int, bn: int, bk: int, n: int
                           ) -> Tuple[int, int, int]:
    """Whole lane-padded rows per output tile (canonical denominator
    semantics); rebalance bm/bk so the VMEM working set stays bounded
    when N is a real model width — <= ROW_SLAB_BYTES per bm-row slab
    (out / pre / residual), <= W_SLAB_BYTES for w."""
    bn = budget.round_up(n, budget.LANE)
    bm = max(budget.SUBLANE, min(bm, budget.slab_rows(bn)))
    bk = max(budget.LANE, min(bk, budget.slab_depth(bn)))
    return bm, bn, bk


# --------------------------------------------------------------------------
# the spec-resolution choke point
# --------------------------------------------------------------------------

def resolve_spec(
    family: str,
    shapes: Sequence[int],
    spec: Optional[KernelSpec] = None,
    *,
    scheme: Optional[str] = None,
    epilogue: Optional[object] = None,
    platform: Optional[str] = None,
) -> KernelSpec:
    """Resolve a (possibly partial) KernelSpec to concrete geometry.

    ``family`` is one of :data:`RESOLVED_FAMILIES`; ``shapes`` the
    family's problem-shape tuple (see there); ``scheme`` / ``epilogue``
    the call's arithmetic scheme and (for ``log_matmul``) epilogue spec
    — both part of the tuning-cache key; ``platform`` defaults to
    ``jax.default_backend()``.

    Per-field precedence: explicit spec field > tuning-cache hit >
    heuristic fallback (off-TPU / cache miss).  Fields a family does
    not use are left untouched.  Norm-epilogue matmul geometry (whole
    padded rows, slab-clamped bm/bk) is enforced *after* resolution on
    every source, preserving the wrapper's historic hard constraint.
    Idempotent: resolving an already-resolved spec is a no-op.
    """
    if family not in RESOLVED_FAMILIES:
        raise KeyError(
            f"unknown kernel family {family!r}; have {RESOLVED_FAMILIES}")
    ks = as_kernel_spec(spec)
    norm = getattr(epilogue, "norm", None)

    needs_bm = family != "flash_attn"
    needs_bn = needs_bk = family == "log_matmul"
    if family == "flash_attn":
        needs_bk = True
    depth_unset = ks.pipeline.depth is None
    unset = ((needs_bm and ks.bm is None)
             or (needs_bn and ks.bn is None)
             or (needs_bk and ks.bk is None)
             or depth_unset)

    bm, bn, bk, depth = ks.bm, ks.bn, ks.bk, ks.pipeline.depth
    if unset:
        hit = _cache_lookup(family, shapes, scheme=scheme,
                            epilogue=epilogue, platform=platform)
        if hit is not None:
            bm = bm if bm is not None else hit.get("bm")
            bn = bn if bn is not None else hit.get("bn")
            bk = bk if bk is not None else hit.get("bk")
            depth = depth if depth is not None else hit.get("depth")

    if family == "log_matmul":
        m, n, k = (int(s) for s in shapes)
        hbm, hbn, hbk = _default_matmul_blocks(m, n, k)
        bm = int(bm) if bm is not None else hbm
        bn = int(bn) if bn is not None else hbn
        bk = int(bk) if bk is not None else hbk
        if norm is not None:
            bm, bn, bk = _rebalance_norm_matmul(bm, bn, bk, n)
    elif family in _ROW_FAMILIES:
        m, n = (int(s) for s in shapes[:2])
        if bm is None:
            bm = _default_row_bm(m, budget.round_up(n, budget.LANE))
        bm = int(bm)
    else:  # flash_attn: bk is the cache chunk size
        bk = int(bk) if bk is not None else budget.LANE
    depth = budget.PIPELINE_BUFFERS if depth is None else int(depth)

    return replace(ks, bm=bm, bn=bn, bk=bk,
                   pipeline=PipelineSpec(depth=depth))


def _cache_lookup(family, shapes, *, scheme, epilogue, platform):
    """Consult the committed tuning cache (lazy import: no cycle, and
    spec construction stays importable without jax)."""
    try:
        from repro.kernels import autotune
    except Exception:  # pragma: no cover - autotune must not be load-bearing
        return None
    return autotune.cached_spec(family, shapes, scheme=scheme,
                                epilogue_kind=epilogue_kind(epilogue),
                                platform=platform)
