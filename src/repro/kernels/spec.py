"""Shared kernel-call contract: one spec from call site to auditor.

Every kernel-family public wrapper (``log_matmul``, the ``fused_*_div``
trio, ``rapid_mul``/``rapid_div``, ``flash_decode_attn``) accepts the
same :class:`KernelSpec`, and ``core/backend.py``'s dispatchers and the
kernel auditor's capture drivers pass the same object through — block
geometry, pipeline depth, interpret mode, scheme and epilogue are one
hashable value instead of a family-specific kwarg soup.

Both dataclasses are frozen (hashable), so a spec can ride ``jax.jit``
static arguments and ``functools.partial`` keywords unchanged.

Pipeline semantics (:class:`PipelineSpec`):

  * ``depth == 1`` — the legacy grid formulation: one tile per grid
    step, HBM->VMEM staging left to Mosaic's hardware-managed grid
    pipeline (which itself double-buffers grid-varying operands).
  * ``depth >= 2`` — explicit software pipelining: the wrapper lowers
    to the manual async-copy kernel, which keeps operands in ANY
    (HBM) memory and rotates ``depth`` VMEM scratch slots per operand,
    starting the DMA for tile ``t+depth-1`` before computing tile
    ``t`` (the paper's pipelined-unit schedule, one slot per stage).

The default depth is :data:`repro.kernels.budget.PIPELINE_BUFFERS`, so
the software-pipelined path is the production default and the budget
module stays the single source of truth for buffer counts.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple, Union

from repro.kernels import budget

__all__ = ["PipelineSpec", "KernelSpec", "as_kernel_spec"]


@dataclass(frozen=True)
class PipelineSpec:
    """How deep the software pipeline stages HBM->VMEM tile copies."""

    #: number of VMEM scratch slots per pipelined operand; 1 disables
    #: the manual pipeline (hardware grid double-buffering only)
    depth: int = budget.PIPELINE_BUFFERS

    def __post_init__(self):
        if not 1 <= int(self.depth) <= 8:
            raise ValueError(
                f"pipeline depth {self.depth} outside [1, 8] "
                "(deeper than 8 slots has no VMEM headroom)")


@dataclass(frozen=True)
class KernelSpec:
    """Uniform kernel-call spec shared by every kernel family.

    ``bm``/``bn``/``bk`` name what the legacy positional ``blocks=``
    tuples carried: rows / lanes / contraction depth per tile.  A
    ``None`` field defers to the family's budget-derived heuristic
    (``_pick_blocks`` / ``_pick_bm``); families without a K dimension
    (the fused dividers, the integer units) ignore ``bk``.
    ``interpret=None`` keeps the per-wrapper CPU autodetect.
    """

    bm: Optional[int] = None
    bn: Optional[int] = None
    bk: Optional[int] = None
    pipeline: PipelineSpec = field(default_factory=PipelineSpec)
    interpret: Optional[bool] = None
    scheme: Optional[str] = None
    epilogue: Optional[object] = None  # repro.core.backend.Epilogue

    @property
    def depth(self) -> int:
        return int(self.pipeline.depth)

    def with_depth(self, depth: int) -> "KernelSpec":
        return replace(self, pipeline=PipelineSpec(depth=depth))

    def blocks_or(self, bm: int, bn: int, bk: int) -> Tuple[int, int, int]:
        """Fill unset block fields from a family heuristic's choice."""
        return (self.bm or bm, self.bn or bn, self.bk or bk)


def as_kernel_spec(
    spec: Union[KernelSpec, Tuple[int, ...], None],
    *,
    blocks: Optional[Tuple[int, ...]] = None,
) -> KernelSpec:
    """Canonicalize a wrapper's ``spec=`` / legacy ``blocks=`` arguments.

    One-release shim: a positional ``(bm, bn, bk)`` (or ``(bm,)`` /
    ``(bm, bn)``) tuple — passed either as ``blocks=`` or directly as
    ``spec=`` — still works but warns with ``DeprecationWarning``;
    named :class:`KernelSpec` fields are the supported surface.
    """
    if blocks is not None and spec is not None:
        raise ValueError("pass spec= or the deprecated blocks=, not both")
    if blocks is not None:
        spec = tuple(blocks)
    if spec is None:
        return KernelSpec()
    if isinstance(spec, KernelSpec):
        return spec
    if isinstance(spec, (tuple, list)):
        warnings.warn(
            "positional blocks=(bm, bn, bk) tuples are deprecated; pass "
            "spec=KernelSpec(bm=..., bn=..., bk=...) instead",
            DeprecationWarning, stacklevel=3)
        dims = tuple(int(b) for b in spec)
        if not 1 <= len(dims) <= 3:
            raise ValueError(f"blocks tuple {spec!r} must have 1-3 entries")
        bm, bn, bk = (dims + (None, None, None))[:3]
        return KernelSpec(bm=bm, bn=bn, bk=bk)
    raise TypeError(
        f"spec must be a KernelSpec or a (bm, bn, bk) tuple, got {spec!r}")
