"""jit'd public wrapper for the log_matmul kernel (padding + dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.core.backend import Epilogue, as_epilogue
from repro.kernels import budget
from repro.kernels.log_matmul.log_matmul import (
    log_matmul_pallas,
    log_matmul_pipelined,
)
from repro.kernels.spec import KernelSpec, as_kernel_spec, resolve_spec

__all__ = ["log_matmul"]


def _check_budget(bm: int, bn: int, bk: int, ep: Epilogue,
                  has_bias: bool, has_residual: bool,
                  depth: int = 1) -> None:
    """Fail an oversized block choice (explicit spec blocks included)
    at call time with the same constant the auditor ratchets on.

    At depth 1 the x/w tiles are hardware double-buffered by the Mosaic
    grid pipeline (``PIPELINE_BUFFERS`` copies); at depth >= 2 they are
    manual VMEM scratch slots, ``depth`` copies each, and nothing else
    buffers them.  Output-row tiles stay grid-BlockSpec'd either way.
    """
    xw_buffers = depth if depth >= 2 else budget.PIPELINE_BUFFERS
    working = xw_buffers * (budget.tile_bytes((bm, bk))
                            + budget.tile_bytes((bk, bn)))
    row_tiles = 1 + has_residual + ep.keep_prenorm       # out, res, pre
    working += budget.PIPELINE_BUFFERS * row_tiles * budget.tile_bytes(
        (bm, bn))
    working += budget.tile_bytes((256,))                 # mul LUT
    if has_bias:
        working += budget.PIPELINE_BUFFERS * budget.tile_bytes((bn,))
    if ep.wants_norm_lut:
        working += budget.tile_bytes((256,))
    budget.check_working_set(working)


def log_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scheme: str | None = None,
    *,
    bias: jnp.ndarray | None = None,
    activation: str | None = None,
    residual: jnp.ndarray | None = None,
    epilogue: Epilogue | None = None,
    spec: KernelSpec | None = None,
    blocks=None,
    interpret: bool | None = None,
):
    """RAPID approximate x @ w (f32). Pads every dim to the block grid.

    ``bias`` ([N]) / ``residual`` ([M, N]) and the ``epilogue`` spec
    (``repro.core.backend.Epilogue`` — activation, rms/softmax norm
    stages; ``activation=`` remains the activation-only sugar) are fused
    into the kernel's output-tile epilogue on its last K visit.  Norm
    epilogues force whole lane-padded rows per output tile so the
    canonical padded-row denominator semantics hold.

    Geometry left unset on ``spec`` is resolved through
    :func:`repro.kernels.spec.resolve_spec` — explicit field > committed
    tuning-cache winner (``TUNE_baseline.json``) > budget heuristic —
    and norm epilogues force whole-row output tiles regardless of
    source.  ``spec`` also carries scheme/epilogue defaults and
    interpret mode; explicit keyword arguments override its fields.
    Depth >= 2 (the default, ``budget.PIPELINE_BUFFERS``) dispatches to
    the software-pipelined kernel whose next K-block DMA overlaps the
    current block's compute; depth 1 keeps the legacy grid formulation.
    Both are bit-exact against each other and the chunk=1 jnp scan.
    Returns the tail, or ``(tail, pre_norm)`` when
    ``epilogue.keep_prenorm``.
    """
    if blocks is not None:
        raise TypeError(
            "log_matmul(blocks=...) was removed; pass "
            "spec=KernelSpec(bm=..., bn=..., bk=...) instead")
    ks = as_kernel_spec(spec)
    scheme = scheme or ks.scheme or "rapid10"
    if epilogue is None:
        epilogue = ks.epilogue
    if interpret is None:
        interpret = ks.interpret
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    ep = as_epilogue(epilogue, activation)
    lut = fa.mul_lut_device(scheme)
    m, k = x.shape
    _, n = w.shape
    ks = resolve_spec("log_matmul", (m, n, k), ks, scheme=scheme,
                      epilogue=ep)
    bm, bn, bk = ks.bm, ks.bn, ks.bk
    depth = ks.depth
    _check_budget(bm, bn, bk, ep, bias is not None, residual is not None,
                  depth=depth)
    unroll = 8 if bk % 8 == 0 else 1
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x.astype(jnp.float32), ((0, pm), (0, pk)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, pk), (0, pn)))
    bp = None
    if bias is not None:
        bp = jnp.pad(bias.astype(jnp.float32), (0, pn))
    rp = None
    if residual is not None:
        rp = jnp.pad(residual.astype(jnp.float32), ((0, pm), (0, pn)))
    dlut = fa.div_lut_device(ep.div_scheme) if ep.wants_norm_lut else None
    if depth >= 2:
        out = log_matmul_pipelined(
            xp, wp, lut, bp, rp, dlut, bm=bm, bn=bn, bk=bk,
            unroll=min(unroll, bk), depth=depth, epilogue=ep, n=n,
            interpret=interpret)
    else:
        out = log_matmul_pallas(
            xp, wp, lut, bp, rp, dlut, bm=bm, bn=bn, bk=bk,
            unroll=min(unroll, bk), epilogue=ep, n=n, interpret=interpret)
    if ep.keep_prenorm:
        return out[0][:m, :n], out[1][:m, :n]
    return out[:m, :n]
