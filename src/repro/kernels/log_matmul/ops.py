"""jit'd public wrapper for the log_matmul kernel (padding + dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.core.backend import Epilogue, as_epilogue
from repro.kernels.fused_div import ref as fdref
from repro.kernels.log_matmul.log_matmul import log_matmul_pallas

__all__ = ["log_matmul"]


def _pick_blocks(m: int, n: int, k: int):
    """Choose hardware-aligned block sizes that fit comfortably in VMEM.

    Every block is clamped to the problem size *rounded up to the
    minimum tile* (8 sublanes x 128 lanes for f32): degenerate dims
    smaller than a tile used to leak through as unaligned block shapes,
    and a K dim between 128 and 512 that was not a multiple of the
    unroll factor silently dropped its tail elements
    (``bk // unroll`` truncated — the smoke-mode shapes exposed this).
    Keeping bk a multiple of 128 keeps it a multiple of any unroll <= 8.
    """
    bm = min(256, -(-m // 8) * 8)
    bn = min(256, -(-n // 128) * 128)
    bk = min(512, -(-k // 128) * 128)
    return bm, bn, bk


def log_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scheme: str = "rapid10",
    *,
    bias: jnp.ndarray | None = None,
    activation: str | None = None,
    residual: jnp.ndarray | None = None,
    epilogue: Epilogue | None = None,
    blocks=None,
    interpret: bool | None = None,
):
    """RAPID approximate x @ w (f32). Pads every dim to the block grid.

    ``bias`` ([N]) / ``residual`` ([M, N]) and the ``epilogue`` spec
    (``repro.core.backend.Epilogue`` — activation, rms/softmax norm
    stages; ``activation=`` remains the activation-only sugar) are fused
    into the kernel's output-tile epilogue on its last K visit.  Norm
    epilogues force whole lane-padded rows per output tile so the
    canonical padded-row denominator semantics hold.  Returns the tail,
    or ``(tail, pre_norm)`` when ``epilogue.keep_prenorm``.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    ep = as_epilogue(epilogue, activation)
    lut = fa.mul_lut_device(scheme)
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = blocks or _pick_blocks(m, n, k)
    if ep.norm is not None:
        # whole lane-padded rows per output tile (canonical denominator
        # semantics); rebalance bm/bk so the VMEM working set stays
        # bounded when N is a real model width — <= 1 MiB of f32 per
        # bm-row slab (out / pre / residual) and <= 2 MiB for the w slab
        bn = fdref.padded_width(n)
        bm = max(8, min(bm, ((1 << 18) // bn) // 8 * 8))
        bk = max(128, min(bk, ((1 << 19) // bn) // 128 * 128))
    unroll = 8 if bk % 8 == 0 else 1
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x.astype(jnp.float32), ((0, pm), (0, pk)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, pk), (0, pn)))
    bp = None
    if bias is not None:
        bp = jnp.pad(bias.astype(jnp.float32), (0, pn))
    rp = None
    if residual is not None:
        rp = jnp.pad(residual.astype(jnp.float32), ((0, pm), (0, pn)))
    dlut = fa.div_lut_device(ep.div_scheme) if ep.wants_norm_lut else None
    out = log_matmul_pallas(xp, wp, lut, bp, rp, dlut, bm=bm, bn=bn, bk=bk,
                            unroll=min(unroll, bk), epilogue=ep, n=n,
                            interpret=interpret)
    if ep.keep_prenorm:
        return out[0][:m, :n], out[1][:m, :n]
    return out[:m, :n]
