"""jit'd public wrapper for the log_matmul kernel (padding + dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.core.backend import normalize_activation
from repro.kernels.log_matmul.log_matmul import log_matmul_pallas

__all__ = ["log_matmul"]


def _pick_blocks(m: int, n: int, k: int):
    """Choose hardware-aligned block sizes that fit comfortably in VMEM."""
    bm = min(256, max(8, m))
    bn = min(256, max(128, n))
    bk = min(512, max(128, k))
    return bm, bn, bk


def log_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scheme: str = "rapid10",
    *,
    bias: jnp.ndarray | None = None,
    activation: str | None = None,
    blocks=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """RAPID approximate x @ w (f32). Pads every dim to the block grid.

    ``bias`` ([N]) and ``activation`` (a ``repro.core.backend.ACTIVATIONS``
    key) are fused into the kernel's output-tile epilogue.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    activation = normalize_activation(activation)
    lut = fa.mul_lut_device(scheme)
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = blocks or _pick_blocks(m, n, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x.astype(jnp.float32), ((0, pm), (0, pk)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, pk), (0, pn)))
    bp = None
    if bias is not None:
        bp = jnp.pad(bias.astype(jnp.float32), (0, pn))
    out = log_matmul_pallas(xp, wp, lut, bp, bm=bm, bn=bn, bk=bk,
                            unroll=min(8, bk), activation=activation,
                            interpret=interpret)
    return out[:m, :n]
