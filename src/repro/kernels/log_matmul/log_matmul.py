"""Pallas TPU kernel: RAPID logarithmic approximate matmul.

TPU adaptation of the paper's pipelined multiplier array.  The FPGA
pipeline stages (LOD -> log-add+coefficient -> anti-log shift) become a
single fused VPU expression per element — on IEEE floats the LOD/anti-log
are free (they are the exponent field), so each approximate product is
one int32 add + one 256-entry coefficient gather.  The *pipelining* that
the paper implements with explicit register stages is realised here by the
Pallas grid pipeline: HBM->VMEM DMA for the next (bm x bk)/(bk x bn) tiles
is overlapped with VPU compute on the current tiles (a hardware-managed
2-deep double buffer per operand — the TPU analogue of the paper's 2-/3-/
4-stage configurations; see DESIGN.md SSPipelining).

Two formulations share the kernel arithmetic:

  * ``log_matmul_pallas`` (pipeline depth 1) — grid (M/bm, N/bn, K/bk);
    M, N are parallel, K is sequential and accumulates into the output
    tile (revisited across the K dimension).  HBM->VMEM staging is left
    to Mosaic's hardware-managed grid pipeline.
  * ``log_matmul_pipelined`` (depth >= 2) — grid (M/bm, N/bn) with the
    K loop *inside* the kernel: x and w stay in ANY (HBM) memory and
    ``depth`` VMEM scratch slots per operand rotate through explicit
    ``make_async_copy`` DMAs, so the copy for K block t+depth-1 is in
    flight while block t's log-domain products compute — the explicit
    software pipeline the paper implements with register stages.  The
    accumulation order (zeros + block_0 + block_1 + ...) is identical
    to the grid formulation, so the two are bit-exact against each
    other and against the chunk=1 jnp scan.

VMEM working set: bm*bk + bk*bn tiles (x depth when manually staged)
+ the bm*bn output tile + the 1 KiB coefficient LUT.  MXU is untouched;
arithmetic is pure VPU int32.

Fused epilogue menu: an optional composition of ``{bias, activation,
residual-add, rms-normalize, softmax-combine}`` is applied to the output
tile on its *last* K-grid visit, while it is still resident in VMEM — a
whole block tail ``norm(activation(out + bias) + residual)`` costs no
extra HBM round-trip, with the normalization divides running through
the RAPID approximate divider.  The epilogue expression is
``repro.core.backend.apply_epilogue_tile``, shared *verbatim* with the
jnp scan path, so the two backends agree bit-for-bit on identically-
ordered accumulations; the norm stages additionally require the output
tile to span the full lane-padded row (``bn == n_pad``; ops.py arranges
this), matching the canonical lane-padded denominator semantics of
``repro.kernels.fused_div.ref``.  With ``Epilogue.keep_prenorm`` the
kernel emits a second output holding the value before the norm stage —
the residual stream a pre-norm transformer block carries forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.backend import Epilogue, apply_epilogue_tile

F32_BIAS = 127 << 23
F32_ABS = 0x7FFFFFFF
F32_SIGN = -0x80000000
MIN_NORMAL = 0x00800000
INF_BITS = 0x7F800000


def _approx_prod(bx_col: jnp.ndarray, bw_row: jnp.ndarray, lut: jnp.ndarray):
    """One rank-1 slab of approximate products from log-domain bits.

    bx_col: [bm, 1] int32 operand bits; bw_row: [1, bn] int32; returns
    [bm, bn] float32 approximate products.
    """
    s1 = bx_col & F32_SIGN
    s2 = bw_row & F32_SIGN
    m1 = bx_col & F32_ABS
    m2 = bw_row & F32_ABS
    i1 = (m1 >> 19) & 0xF
    i2 = (m2 >> 19) & 0xF
    c = lut[(i1 * 16 + i2).astype(jnp.int32)]
    s = m1 + m2 - F32_BIAS + c
    s = jnp.where(s < MIN_NORMAL, 0, s)
    s = jnp.where(s >= INF_BITS, INF_BITS, s)
    dead = (m1 < MIN_NORMAL) | (m2 < MIN_NORMAL)
    s = jnp.where(dead, 0, s)
    return jax.lax.bitcast_convert_type(s | (s1 ^ s2), jnp.float32)


def _kernel(x_ref, w_ref, lut_ref, *rest, bk: int, unroll: int, nk: int,
            ep: Epilogue, has_bias: bool, has_residual: bool, n: int):
    """Accumulate one (bm, bn) output tile over the current K block."""
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    res_ref = refs.pop(0) if has_residual else None
    dlut_ref = refs.pop(0) if ep.wants_norm_lut else None
    if ep.keep_prenorm:
        o_ref, pre_ref = refs
    else:
        (o_ref,) = refs

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bx = jax.lax.bitcast_convert_type(x_ref[...], jnp.int32)  # [bm, bk]
    bw = jax.lax.bitcast_convert_type(w_ref[...], jnp.int32)  # [bk, bn]
    o_ref[...] += _accumulate_block(bx, bw, lut_ref[...], o_ref[...],
                                    bk, unroll)

    if has_bias or has_residual or not ep.is_identity:
        # epilogue menu on the tile's final K visit, while it sits in
        # VMEM — the shared canonical expression (apply_epilogue_tile)
        @pl.when(pl.program_id(2) == nk - 1)
        def _epilogue():
            out = apply_epilogue_tile(
                o_ref[...],
                bias_ref[...] if has_bias else None,
                res_ref[...] if has_residual else None,
                ep, n=n,
                div_lut=dlut_ref[...] if dlut_ref is not None else None)
            if ep.keep_prenorm:
                o_ref[...], pre_ref[...] = out
            else:
                o_ref[...] = out


def _accumulate_block(bx, bw, lut, out_like, bk: int, unroll: int):
    """Zeros + sum of rank-1 slabs over one K block (the canonical
    accumulation order both formulations and the chunk=1 scan share)."""

    def body(t, acc):
        for u in range(unroll):
            kk = t * unroll + u
            acc = acc + _approx_prod(bx[:, kk][:, None], bw[kk, :][None, :],
                                     lut)
        return acc

    return jax.lax.fori_loop(0, bk // unroll, body, jnp.zeros_like(out_like))


def _pipelined_kernel(x_hbm, w_hbm, lut_ref, *rest, bm: int, bn: int,
                      bk: int, unroll: int, nk: int, depth: int,
                      ep: Epilogue, has_bias: bool, has_residual: bool,
                      n: int):
    """One (bm, bn) output tile with the K loop software-pipelined.

    x/w live in ANY (HBM) memory; ``depth`` VMEM slots per operand
    rotate through explicit DMAs so the copy of K block t+depth-1
    overlaps block t's compute.  Each K block is started exactly once
    and waited exactly once, so the DMA semaphores balance per grid
    step; the output tile is written once (no grid revisits).
    """
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    res_ref = refs.pop(0) if has_residual else None
    dlut_ref = refs.pop(0) if ep.wants_norm_lut else None
    x_scr, w_scr, x_sem, w_sem = refs[-4:]
    refs = refs[:-4]
    if ep.keep_prenorm:
        o_ref, pre_ref = refs
    else:
        (o_ref,) = refs

    i = pl.program_id(0)
    j = pl.program_id(1)

    def x_dma(slot, kk):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)],
            x_scr.at[slot], x_sem.at[slot])

    def w_dma(slot, kk):
        return pltpu.make_async_copy(
            w_hbm.at[pl.ds(kk * bk, bk), pl.ds(j * bn, bn)],
            w_scr.at[slot], w_sem.at[slot])

    # warm-up: put the first depth-1 K blocks in flight
    for d in range(depth - 1):
        @pl.when(d < nk)
        def _start(d=d):
            x_dma(d % depth, d).start()
            w_dma(d % depth, d).start()

    lut = lut_ref[...]

    def k_step(kk, acc):
        slot = jax.lax.rem(kk, depth)
        nxt = kk + depth - 1

        @pl.when(nxt < nk)
        def _prefetch():
            x_dma(jax.lax.rem(nxt, depth), nxt).start()
            w_dma(jax.lax.rem(nxt, depth), nxt).start()

        x_dma(slot, kk).wait()
        w_dma(slot, kk).wait()
        bx = jax.lax.bitcast_convert_type(x_scr[slot], jnp.int32)
        bw = jax.lax.bitcast_convert_type(w_scr[slot], jnp.int32)
        return acc + _accumulate_block(bx, bw, lut, acc, bk, unroll)

    acc = jax.lax.fori_loop(
        0, nk, k_step, jnp.zeros((bm, bn), jnp.float32))

    if has_bias or has_residual or not ep.is_identity:
        out = apply_epilogue_tile(
            acc,
            bias_ref[...] if has_bias else None,
            res_ref[...] if has_residual else None,
            ep, n=n,
            div_lut=dlut_ref[...] if dlut_ref is not None else None)
        if ep.keep_prenorm:
            o_ref[...], pre_ref[...] = out
        else:
            o_ref[...] = out
    else:
        o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "unroll", "depth", "epilogue", "n",
                     "interpret"),
)
def log_matmul_pipelined(
    x: jnp.ndarray,
    w: jnp.ndarray,
    lut: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    residual: jnp.ndarray | None = None,
    div_lut: jnp.ndarray | None = None,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    unroll: int = 8,
    depth: int = 2,
    epilogue: Epilogue = Epilogue(),
    n: int | None = None,
    interpret: bool = False,
):
    """Software-pipelined x[M,K] @ w[K,N_pad]; contract as
    :func:`log_matmul_pallas` plus ``depth`` explicit DMA slots."""
    m, k = x.shape
    _, npad = w.shape
    if n is None:
        n = npad
    if epilogue.norm is not None and bn != npad:
        raise ValueError(
            f"norm epilogue needs whole rows per tile: bn={bn} != N={npad}")
    if epilogue.wants_norm_lut and div_lut is None:
        raise ValueError("epilogue.div_scheme set but no div_lut operand")
    grid = (m // bm, npad // bn)
    nk = k // bk
    has_bias = bias is not None
    has_residual = residual is not None
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    in_specs = [
        any_spec,                                    # x: manual DMA
        any_spec,                                    # w: manual DMA
        pl.BlockSpec((256,), lambda i, j: (0,)),     # mul LUT
    ]
    operands = [x, w, lut]
    if has_bias:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j: (j,)))
        operands.append(bias)
    if has_residual:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j: (i, j)))
        operands.append(residual)
    if epilogue.wants_norm_lut:
        in_specs.append(pl.BlockSpec((256,), lambda i, j: (0,)))
        operands.append(div_lut)
    out_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, npad), jnp.float32)
    if epilogue.keep_prenorm:
        out_specs, out_shapes = [out_spec, out_spec], [out_shape, out_shape]
    else:
        out_specs, out_shapes = out_spec, out_shape
    return pl.pallas_call(
        functools.partial(_pipelined_kernel, bm=bm, bn=bn, bk=bk,
                          unroll=unroll, nk=nk, depth=depth, ep=epilogue,
                          has_bias=has_bias, has_residual=has_residual, n=n),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((depth, bm, bk), jnp.float32),
            pltpu.VMEM((depth, bk, bn), jnp.float32),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "unroll", "epilogue", "n", "interpret"),
)
def log_matmul_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    lut: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    residual: jnp.ndarray | None = None,
    div_lut: jnp.ndarray | None = None,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    unroll: int = 8,
    epilogue: Epilogue = Epilogue(),
    n: int | None = None,
    interpret: bool = False,
):
    """x[M,K] @ w[K,N_pad] with RAPID approximate products. f32 in/out.

    M, N_pad, K must be divisible by the block sizes (ops.py pads);
    ``bias`` ([N_pad]) / ``residual`` ([M, N_pad]) and the ``epilogue``
    spec are fused into the output tile's last K visit.  ``n`` is the
    real (pre-padding) output width the rms norm stage averages over;
    norm epilogues additionally require ``bn == N_pad`` (whole rows per
    tile) and ``div_lut`` when ``epilogue.div_scheme`` is set.  Returns
    the tail, or ``(tail, pre_norm)`` when ``epilogue.keep_prenorm``.
    """
    m, k = x.shape
    _, npad = w.shape
    if n is None:
        n = npad
    if epilogue.norm is not None and bn != npad:
        raise ValueError(
            f"norm epilogue needs whole rows per tile: bn={bn} != N={npad}")
    if epilogue.wants_norm_lut and div_lut is None:
        raise ValueError("epilogue.div_scheme set but no div_lut operand")
    grid = (m // bm, npad // bn, k // bk)
    has_bias = bias is not None
    has_residual = residual is not None
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((256,), lambda i, j, kk: (0,)),
    ]
    operands = [x, w, lut]
    if has_bias:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        operands.append(bias)
    if has_residual:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        operands.append(residual)
    if epilogue.wants_norm_lut:
        in_specs.append(pl.BlockSpec((256,), lambda i, j, kk: (0,)))
        operands.append(div_lut)
    out_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, npad), jnp.float32)
    if epilogue.keep_prenorm:
        out_specs, out_shapes = [out_spec, out_spec], [out_shape, out_shape]
    else:
        out_specs, out_shapes = out_spec, out_shape
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk, unroll=unroll, nk=grid[2],
                          ep=epilogue, has_bias=has_bias,
                          has_residual=has_residual, n=n),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(*operands)
