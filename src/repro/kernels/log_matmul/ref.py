"""Pure-jnp oracle for the log_matmul Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import float_approx as fa


def log_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Unchunked reference: materialises the full [M,K,N] product tensor."""
    prod = fa.log_mul_f32(
        x.astype(jnp.float32)[:, :, None], w.astype(jnp.float32)[None, :, :], lut
    )
    return prod.sum(axis=1)
