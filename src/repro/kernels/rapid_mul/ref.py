"""Oracle for the rapid_mul kernel: the core jnp Mitchell multiplier."""
from repro.core.mitchell import mitchell_mul as rapid_mul_ref  # noqa: F401
