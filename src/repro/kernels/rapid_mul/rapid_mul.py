"""Pallas TPU kernel: elementwise RAPID integer multiplier (8/16-bit ops).

The faithful port of the paper's integer unit: leading-one detection via
smear+popcount (the VPU analogue of the 4-bit segmented LOD), fraction
alignment, ternary add (frac1 + frac2 + coefficient in one pass — on TPU
a single fused int add chain), anti-log barrel shift.  Tiled over a 2D
grid of (rows, 128-lane) blocks; the grid pipeline double-buffers the
HBM<->VMEM transfers, standing in for the paper's register pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitops import ilog2


def _kernel(a_ref, b_ref, lut_ref, o_ref, *, n_bits: int):
    F = n_bits - 1
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    lut = lut_ref[...]

    k1 = ilog2(jnp.maximum(a, 1))
    k2 = ilog2(jnp.maximum(b, 1))
    f1 = (a - (jnp.int32(1) << k1)) << (F - k1)
    f2 = (b - (jnp.int32(1) << k2)) << (F - k2)
    i1 = (f1 >> (F - 4)) & 0xF
    i2 = (f2 >> (F - 4)) & 0xF
    c = lut[(i1 * 16 + i2).astype(jnp.int32)]

    s = f1 + f2 + c
    one = jnp.int32(1) << F
    carry = (s >= one).astype(jnp.int32)
    mant = jnp.maximum(jnp.where(carry == 1, s, s + one), 0).astype(jnp.uint32)
    shift = k1 + k2 + carry - F
    pos = jnp.maximum(shift, 0).astype(jnp.uint32)
    neg = jnp.maximum(-shift, 0).astype(jnp.uint32)
    res = (mant << pos) >> neg
    hi = ilog2(jnp.maximum(mant.astype(jnp.int32), 1)) + shift
    res = jnp.where(hi >= 32, jnp.uint32(0xFFFFFFFF), res)
    o_ref[...] = jnp.where((a == 0) | (b == 0), jnp.uint32(0), res)


@functools.partial(jax.jit, static_argnames=("n_bits", "block", "interpret"))
def rapid_mul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    n_bits: int = 16,
    block: tuple = (64, 128),
    interpret: bool = False,
) -> jnp.ndarray:
    """Elementwise approximate a*b on (R, 128k)-shaped uint arrays."""
    r, ccols = a.shape
    br, bc = block
    grid = (r // br, ccols // bc)
    return pl.pallas_call(
        functools.partial(_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((256,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, ccols), jnp.uint32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(a, b, lut)
