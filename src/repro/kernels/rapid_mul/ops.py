"""jit'd wrapper for rapid_mul: flatten, pad to the block grid, dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mitchell, schemes
from repro.kernels.rapid_mul.rapid_mul import rapid_mul_pallas
from repro.kernels.spec import KernelSpec, as_kernel_spec

__all__ = ["rapid_mul"]


def rapid_mul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    scheme: str | None = None,
    n_bits: int = 16,
    interpret: bool | None = None,
    *,
    spec: KernelSpec | None = None,
) -> jnp.ndarray:
    """Elementwise RAPID approximate product of unsigned ints < 2**n_bits.

    Accepts the shared :class:`repro.kernels.spec.KernelSpec` for
    scheme/interpret/block defaults; the integer unit is a single-pass
    elementwise map, so ``spec.pipeline.depth`` has no software pipeline
    to select and is ignored (the grid pipeline already overlaps tile
    DMA with compute).
    """
    ks = as_kernel_spec(spec)
    scheme = scheme or ks.scheme or "rapid10"
    if interpret is None:
        interpret = ks.interpret
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    # memoized per (scheme, n_bits): one host build + one upload ever
    lut = mitchell.lut_device(schemes.MUL_SCHEMES[scheme], n_bits - 1)
    shape = a.shape
    af = a.reshape(-1).astype(jnp.uint32)
    bf = b.reshape(-1).astype(jnp.uint32)
    bc = ks.bn or 128
    br = ks.bm or 8
    pad = (-af.size) % (br * bc)
    af = jnp.pad(af, (0, pad)).reshape(-1, bc)
    bf = jnp.pad(bf, (0, pad)).reshape(-1, bc)
    rows = af.shape[0]
    rpad = (-rows) % br
    af = jnp.pad(af, ((0, rpad), (0, 0)))
    bf = jnp.pad(bf, ((0, rpad), (0, 0)))
    out = rapid_mul_pallas(af, bf, lut, n_bits=n_bits, block=(br, bc),
                           interpret=interpret)
    return out.reshape(-1)[: a.size].reshape(shape)
