"""Oracle for the rapid_div kernel: the core jnp Mitchell divider."""
from repro.core.mitchell import mitchell_div as rapid_div_ref  # noqa: F401
