"""jit'd wrapper for rapid_div: flatten, pad to the block grid, dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mitchell, schemes
from repro.kernels.rapid_div.rapid_div import rapid_div_pallas
from repro.kernels.spec import KernelSpec, as_kernel_spec

__all__ = ["rapid_div"]


def rapid_div(
    a: jnp.ndarray,
    b: jnp.ndarray,
    scheme: str | None = None,
    n_bits: int = 8,
    interpret: bool | None = None,
    *,
    spec: KernelSpec | None = None,
) -> jnp.ndarray:
    """Elementwise RAPID a/b: a < 2**(2*n_bits), b < 2**n_bits.

    Accepts the shared :class:`repro.kernels.spec.KernelSpec` for
    scheme/interpret/block defaults; like :func:`rapid_mul`, the
    single-pass elementwise map has no software pipeline, so
    ``spec.pipeline.depth`` is ignored.
    """
    ks = as_kernel_spec(spec)
    scheme = scheme or ks.scheme or "rapid9"
    if interpret is None:
        interpret = ks.interpret
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    # memoized per (scheme, n_bits): one host build + one upload ever
    lut = mitchell.lut_device(schemes.DIV_SCHEMES[scheme], 2 * n_bits - 1)
    shape = a.shape
    af = a.reshape(-1).astype(jnp.uint32)
    bf = b.reshape(-1).astype(jnp.uint32)
    bc, br = ks.bn or 128, ks.bm or 8
    pad = (-af.size) % (br * bc)
    af = jnp.pad(af, (0, pad), constant_values=1).reshape(-1, bc)
    bf = jnp.pad(bf, (0, pad), constant_values=1).reshape(-1, bc)
    rows = af.shape[0]
    rpad = (-rows) % br
    af = jnp.pad(af, ((0, rpad), (0, 0)), constant_values=1)
    bf = jnp.pad(bf, ((0, rpad), (0, 0)), constant_values=1)
    out = rapid_div_pallas(af, bf, lut, n_bits=n_bits, block=(br, bc),
                           interpret=interpret)
    return out.reshape(-1)[: a.size].reshape(shape)
