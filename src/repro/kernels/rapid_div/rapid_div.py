"""Pallas TPU kernel: elementwise RAPID integer divider (2N-by-N).

Same LOD/align/ternary-add structure as rapid_mul, with log subtraction
and a borrow branch instead of a carry (paper Eq. 5/7).  The paper's key
point — that Mitchell's transform collapses the long iterative divider
array into one subtractor, bringing divide latency down to multiply
latency — carries over verbatim: this kernel has the *same* op count and
pipeline depth as rapid_mul (on TPU there is no iterative integer divide
unit at all; exact integer division lowers to a multi-op sequence, so the
win is even larger).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitops import ilog2


def _kernel(a_ref, b_ref, lut_ref, o_ref, *, n_bits: int):
    F = 2 * n_bits - 1
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    lut = lut_ref[...]

    k1 = ilog2(jnp.maximum(a, 1))
    k2 = ilog2(jnp.maximum(b, 1))
    f1 = (a - (jnp.int32(1) << k1)) << (F - k1)
    f2 = (b - (jnp.int32(1) << k2)) << (F - k2)
    i1 = (f1 >> (F - 4)) & 0xF
    i2 = (f2 >> (F - 4)) & 0xF
    c = lut[(i1 * 16 + i2).astype(jnp.int32)]

    s = f1 - f2 + c
    one = jnp.int32(1) << F
    borrow = (s < 0).astype(jnp.int32)
    mant = jnp.maximum(jnp.where(borrow == 1, s + 2 * one, s + one), 0)
    shift = k1 - k2 - borrow - F
    pos = jnp.maximum(shift, 0).astype(jnp.uint32)
    neg = jnp.minimum(jnp.maximum(-shift, 0), 31).astype(jnp.uint32)
    res = (mant.astype(jnp.uint32) << pos) >> neg
    res = jnp.where(a == 0, jnp.uint32(0), res)
    sat = jnp.uint32((1 << (2 * n_bits)) - 1)
    o_ref[...] = jnp.where(b == 0, sat, res)


@functools.partial(jax.jit, static_argnames=("n_bits", "block", "interpret"))
def rapid_div_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    n_bits: int = 8,
    block: tuple = (64, 128),
    interpret: bool = False,
) -> jnp.ndarray:
    r, ccols = a.shape
    br, bc = block
    grid = (r // br, ccols // bc)
    return pl.pallas_call(
        functools.partial(_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((256,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, ccols), jnp.uint32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel"))
        ) if not interpret else None,
        interpret=interpret,
    )(a, b, lut)
