"""Fault-tolerant checkpointing: atomic, mesh-agnostic, keep-N.

Design for 1000+ node operation:

  * **Atomicity** — writes go to ``step_XXXX.tmp/`` and are renamed into
    place only after every array and the manifest have been flushed, so a
    preemption mid-write can never corrupt the latest checkpoint;
  * **Mesh-agnostic restore** — arrays are stored as full logical arrays
    (gathered per leaf); restore re-shards onto *whatever* mesh/sharding
    the restarted job uses.  A job can restart on a different pod count
    (elastic re-scale) as long as the new sharding divides the shapes;
  * **Data-pipeline state** — the manifest carries (step, data cursor,
    rng), so resume is bit-deterministic;
  * **Keep-N GC** — old checkpoints are pruned only after a newer one is
    durable.

On a real multi-host cluster each host would write only its owned shards
(process-local slices); on this single-process reference implementation
the gather is a no-op.  The on-disk format (one ``.npy`` per leaf + JSON
manifest) is intentionally dependency-free.
"""
from __future__ import annotations

import json
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "."


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -------------------------------------------------------------- save
    def save(self, step: int, params, opt_state, extra: Optional[dict] = None):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        manifest: dict[str, Any] = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "arrays": {},
        }
        for tree, prefix in ((params, "params"), (opt_state, "opt")):
            for key, leaf in _flatten(tree).items():
                arr = np.asarray(jax.device_get(leaf))
                name = f"{prefix}{_SEP}{key}"
                np.save(tmp / (name + ".npy"), arr)
                manifest["arrays"][name] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ----------------------------------------------------------- restore
    def all_steps(self):
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        ]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, step: Optional[int], params_like, opt_like,
                shardings=None, opt_shardings=None):
        """Restore into the given pytree structures; reshard if asked.

        ``params_like``/``opt_like`` provide structure; ``shardings``
        trees (optional) re-place every leaf on the current mesh.
        """
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())

        def load(tree, prefix, shard_tree):
            flat_keys = list(_flatten(tree).keys())
            leaves, treedef = jax.tree_util.tree_flatten(tree)
            shard_leaves = (jax.tree_util.tree_flatten(shard_tree)[0]
                            if shard_tree is not None else [None] * len(leaves))
            out = []
            for key, like, shd in zip(flat_keys, leaves, shard_leaves):
                arr = np.load(cdir / f"{prefix}{_SEP}{key}.npy")
                if shd is not None:
                    out.append(jax.device_put(arr, shd))
                else:
                    out.append(jax.numpy.asarray(arr))
            return jax.tree_util.tree_unflatten(treedef, out)

        params = load(params_like, "params", shardings)
        opt = load(opt_like, "opt", opt_shardings)
        return step, params, opt, manifest.get("extra", {})
