"""Block-paged KV cache: fixed page geometry + host-side free-list alloc.

The device side is a per-layer page *pool* ``[n_pages, page_size, KV, hd]``
(specs from ``Model.cache_specs(..., n_pages=, page_size=)``); slots own
pages through an int32 page table ``[n_slots, pages_per_slot]`` that the
decode step indirects every read/write through (the ring-write of the
dense cache generalized to table lookup).  This module is the host-side
bookkeeping: geometry arithmetic and the free-list allocator that makes
KV memory scale with *live tokens* instead of ``n_slots * cache_n``.

Page 0 is a reserved scratch page: it is never handed out, table rows of
empty slots point at it, and invalid-token writes are redirected there,
so a fixed-shape compiled step can always write somewhere harmless.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

__all__ = ["SCRATCH_PAGE", "PageGeometry", "PageAllocator"]

#: Reserved pool page absorbing writes from inactive/padded positions.
SCRATCH_PAGE = 0


@dataclass(frozen=True)
class PageGeometry:
    """Fixed page geometry — pinned at engine build so shapes never change.

    ``n_pages`` counts the scratch page; ``usable_pages`` excludes it.
    """

    page_size: int
    n_pages: int
    pages_per_slot: int

    def __post_init__(self):
        if self.page_size < 1 or self.pages_per_slot < 1:
            raise ValueError(f"degenerate page geometry {self}")
        if self.n_pages < 2:
            raise ValueError(
                f"n_pages={self.n_pages} leaves no usable page after the "
                f"scratch page {SCRATCH_PAGE}")

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV entries."""
        return max(1, -(-n_tokens // self.page_size))

    @property
    def slot_capacity(self) -> int:
        """Max tokens one slot can address through its page table."""
        return self.pages_per_slot * self.page_size

    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1

    @property
    def token_capacity(self) -> int:
        """Total live tokens the pool can hold across all slots."""
        return self.usable_pages * self.page_size


class PageAllocator:
    """Free-list allocator over the pool's usable pages.

    Allocation is all-or-nothing (a request reserves its worst case at
    admission, so decode can never deadlock mid-generation) and freeing
    a page twice raises — the leak invariant CI asserts is exactly
    ``n_free == usable_pages`` after a drained burst.
    """

    def __init__(self, geom: PageGeometry):
        self.geom = geom
        # ascending hand-out order (pop from the front) purely for
        # debuggability; correctness never depends on which page you get
        self._free: List[int] = list(range(1, geom.n_pages))
        self._live: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._live)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages, or None (and no change) if not available."""
        if n > len(self._free):
            return None
        pages, self._free = self._free[:n], self._free[n:]
        self._live.update(pages)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._live:
                raise ValueError(f"double free / foreign page {p}")
            self._live.discard(p)
        self._free.extend(pages)
