"""Batched serving engine: prefill + decode with a fixed-slot batch.

A deliberately small but real engine: requests are admitted into B slots;
prefill produces the KV cache for a whole batch, then tokens stream out
of ``decode_step``.  Greedy or temperature sampling.  The cache geometry
(cache_n) is fixed at engine build so the decode step compiles once.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as be
from repro.models.layers import ParallelCtx
from repro.models.model import Model

__all__ = ["ServeEngine"]


@dataclass
class ServeEngine:
    model: Model
    params: dict
    ctx: ParallelCtx
    cache_n: int = 256
    temperature: float = 0.0
    seed: int = 0
    # approximate-arithmetic backend (registry name); None defers to the
    # model config's per-site map / env / hardware autodetect, an
    # explicit name overrides every site.  Resolved once at engine build
    # so prefill+decode compile against pinned per-site backends — on a
    # multi-device TPU, auto sites pin as backend.AUTO_HW, which
    # resolves per call site at trace time (jnp under pjit, pallas
    # inside shard_map bodies) from the memoized hardware probe only,
    # so post-build env changes still cannot flip the compiled kernels.
    backend: Optional[str] = None

    def __post_init__(self):
        pinned = be.pin_backends(self.model.cfg.approx, self.backend)
        if pinned != self.model.cfg.approx:
            self.model = Model(self.model.cfg.with_(approx=pinned))
        self.backend = pinned.backend_for("default")
        self._decode = jax.jit(
            lambda p, t, c: self.model.decode_step(p, t, c, self.ctx))
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.ctx, self.cache_n))

    def _sample(self, logits: jnp.ndarray, step: int) -> jnp.ndarray:
        """Sample the whole batch's next tokens (draw index ``step``).

        Per-request keys: request ``i``'s k-th draw uses
        ``fold_in(fold_in(root, i), k)`` — the root key is only ever
        folded, no key is used twice, and a request's sampled tokens
        do not depend on which requests co-reside in the batch.
        """
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        root = jax.random.PRNGKey(self.seed)
        rows = [
            jax.random.categorical(
                jax.random.fold_in(jax.random.fold_in(root, i), step),
                logits[i] / self.temperature)
            for i in range(logits.shape[0])
        ]
        return jnp.stack(rows).astype(jnp.int32)

    def generate(self, prompts: List[List[int]], max_new: int = 32,
                 stop_token: Optional[int] = None) -> List[List[int]]:
        """Pad prompts to a common length, prefill, decode max_new tokens.

        A sampled ``stop_token`` terminates its request *without being
        emitted*: outputs never contain the stop token.
        """
        B = len(prompts)
        plen = max(len(p) for p in prompts)
        if plen + max_new > self.cache_n:
            raise ValueError(
                f"longest prompt ({plen} tokens) + max_new ({max_new}) = "
                f"{plen + max_new} exceeds cache_n ({self.cache_n})")
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p  # left-pad (uniform positions)
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch)

        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        tok = self._sample(logits, 0)
        for step in range(max_new):
            t = np.asarray(tok)
            for i in range(B):
                if not done[i]:
                    if stop_token is not None and t[i] == stop_token:
                        done[i] = True
                    else:
                        out[i].append(int(t[i]))
            if done.all() or step == max_new - 1:
                break
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits, step + 1)
        return out
