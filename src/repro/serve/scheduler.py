"""Continuous-batching serve engine: request queue + paged KV + streaming.

The fixed-slot :class:`~repro.serve.engine.ServeEngine` prefills a batch
together and decodes it in lockstep — a finished sequence burns its slot
until the whole batch drains, and cache memory is ``B * cache_n`` no
matter how short the requests are.  This engine keeps every decode slot
busy instead, the ReservationStations idiom of a pipelined ALU applied
to serving:

  * requests queue in FCFS order and are *admitted* into any free slot
    the moment the page allocator can cover their worst case
    (``len(prompt) + max_new`` tokens of KV);
  * prompts prefill in fixed-size chunks *interleaved* with decode
    ticks, so long prompts never stall ongoing generations;
  * KV lives in a block-paged pool (``repro.serve.paged_kv``) addressed
    through per-slot page tables — memory scales with live tokens;
  * finished requests free their pages and slot immediately (slot
    recycling / eviction) and their tokens stream out per request as
    :class:`StreamEvent`s.

Both engine phases run through one compiled ``Model.decode_paged``; the
decode tick has fixed ``[n_slots, 1]`` shapes with a dynamic occupancy
mask (``n_valid``), so it compiles exactly once, and the prefill tick
has fixed ``[1, prefill_chunk]`` shapes, so it too compiles once.

Sampling keys derive per request: ``fold_in(fold_in(root, rid), k)`` for
a request's k-th draw — a request's sampled tokens are deterministic
and independent of which requests happen to co-reside in the batch.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as be
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.serve.paged_kv import PageAllocator, PageGeometry

__all__ = ["StreamEvent", "ContinuousServeEngine"]


@dataclass(frozen=True)
class StreamEvent:
    """One streamed output: a token for ``rid``, and/or its completion.

    ``token`` is None on a pure completion event (stop token seen — the
    stop token itself is never emitted — or the request was cancelled).
    """

    rid: int
    token: Optional[int]
    done: bool


@dataclass
class _Queued:
    rid: int
    prompt: List[int]
    max_new: int
    stop_token: Optional[int]


@dataclass
class _Slot:
    rid: int
    prompt: List[int]
    max_new: int
    stop_token: Optional[int]
    pages: List[int]
    n_prefilled: int = 0
    length: int = 0              # KV tokens stored for this slot
    last_token: Optional[int] = None   # pending token to feed to decode
    n_generated: int = 0
    n_sampled: int = 0           # sampling-key counter (includes stop draw)
    out: List[int] = field(default_factory=list)


class ContinuousServeEngine:
    """Continuous-batching engine over a block-paged KV cache.

    ``max_len`` bounds one request's total tokens (prompt + generated)
    and fixes the per-slot page-table width; ``n_pages`` sizes the
    shared pool (default: every slot can be full simultaneously — the
    same peak KV memory as a fixed-slot engine with ``cache_n ==
    max_len``, but shorter requests leave their pages to others).
    """

    def __init__(self, model: Model, params: dict,
                 ctx: Optional[ParallelCtx] = None, n_slots: int = 4,
                 max_len: int = 256, page_size: int = 16,
                 n_pages: Optional[int] = None, prefill_chunk: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 backend: Optional[str] = None):
        if model.cfg.family not in ("dense", "moe"):
            raise ValueError(
                "continuous batching serves decoder-only text families "
                f"(dense/moe); got {model.cfg.family!r}")
        pinned = be.pin_backends(model.cfg.approx, backend)
        if pinned != model.cfg.approx:
            model = Model(model.cfg.with_(approx=pinned))
        self.model = model
        self.params = params
        self.ctx = ctx or ParallelCtx()
        self.backend = pinned.backend_for("default")
        self.n_slots = n_slots
        self.prefill_chunk = prefill_chunk
        self.temperature = temperature
        self.seed = seed

        pages_per_slot = -(-max_len // page_size)
        if n_pages is None:
            n_pages = n_slots * pages_per_slot + 1  # + scratch page
        self.geom = PageGeometry(page_size, n_pages, pages_per_slot)
        self.alloc = PageAllocator(self.geom)
        self.page_table = np.zeros((n_slots, pages_per_slot), np.int32)
        self.cache = model.init_paged_cache(n_pages, page_size)

        self._queue: deque = deque()
        self._slots: List[Optional[_Slot]] = [None] * n_slots
        self._next_rid = 0
        self._root_key = jax.random.PRNGKey(seed)
        # trace-time counters: each jit retrace == one compile, so the
        # bench gate can assert "decode recompiles at most once"
        self.trace_counts = {"decode": 0, "prefill": 0}

        def _count(name):
            self.trace_counts[name] += 1

        self._decode = jax.jit(
            lambda p, c, t, pt, off, nv: (
                _count("decode"),
                self.model.decode_paged(p, t, c, pt, off, nv, self.ctx),
            )[1])
        self._prefill = jax.jit(
            lambda p, c, t, pt, off, nv: (
                _count("prefill"),
                self.model.decode_paged(p, t, c, pt, off, nv, self.ctx),
            )[1])

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 32,
               stop_token: Optional[int] = None) -> int:
        """Queue a request; returns its rid.  Admission happens inside
        :meth:`step` as soon as a slot and enough pages free up."""
        total = len(prompt) + max_new
        if not prompt or max_new < 1:
            raise ValueError(
                f"need a non-empty prompt ({len(prompt)}) and max_new >= 1 "
                f"({max_new})")
        if total > self.geom.slot_capacity:
            raise ValueError(
                f"prompt length {len(prompt)} + max_new {max_new} = {total} "
                f"exceeds slot capacity {self.geom.slot_capacity} "
                f"({self.geom.pages_per_slot} pages x {self.geom.page_size})")
        if self.geom.pages_for(total) > self.geom.usable_pages:
            raise ValueError(
                f"request needs {self.geom.pages_for(total)} pages; pool "
                f"has only {self.geom.usable_pages} usable pages")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Queued(rid, list(prompt), max_new, stop_token))
        return rid

    def cancel(self, rid: int) -> bool:
        """Evict a queued or running request; frees its slot and pages."""
        for i, q in enumerate(self._queue):
            if q.rid == rid:
                del self._queue[i]
                return True
        for b, s in enumerate(self._slots):
            if s is not None and s.rid == rid:
                self._evict(b)
                return True
        return False

    @property
    def pending(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    @property
    def n_live_tokens(self) -> int:
        return sum(s.length for s in self._slots if s is not None)

    def _evict(self, b: int) -> None:
        slot = self._slots[b]
        self.alloc.free(slot.pages)
        self.page_table[b, :] = 0
        self._slots[b] = None

    def _admit(self) -> None:
        """FCFS admission: head of queue waits for slot + pages (no
        skip-ahead, so a big request cannot starve)."""
        for b in range(self.n_slots):
            if not self._queue or self._slots[b] is not None:
                continue
            req = self._queue[0]
            pages = self.alloc.alloc(
                self.geom.pages_for(len(req.prompt) + req.max_new))
            if pages is None:
                break
            self._queue.popleft()
            self.page_table[b, :] = 0
            self.page_table[b, :len(pages)] = pages
            self._slots[b] = _Slot(req.rid, req.prompt, req.max_new,
                                   req.stop_token, pages)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _sample(self, logits_row, slot: _Slot) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        key = jax.random.fold_in(
            jax.random.fold_in(self._root_key, slot.rid), slot.n_sampled)
        slot.n_sampled += 1
        return int(jax.random.categorical(
            key, jnp.asarray(logits_row) / self.temperature))

    def _emit(self, b: int, tok: int, events: List[StreamEvent]) -> None:
        slot = self._slots[b]
        if slot.stop_token is not None and tok == slot.stop_token:
            # stop token terminates the request without being emitted
            events.append(StreamEvent(slot.rid, None, True))
            self._evict(b)
            return
        slot.out.append(tok)
        slot.n_generated += 1
        done = slot.n_generated >= slot.max_new
        events.append(StreamEvent(slot.rid, tok, done))
        if done:
            self._evict(b)
        else:
            slot.last_token = tok

    # ------------------------------------------------------------------
    # engine loop
    # ------------------------------------------------------------------
    def step(self) -> List[StreamEvent]:
        """One engine tick: admit, one prefill chunk, one decode step."""
        events: List[StreamEvent] = []
        self._admit()

        # chunked prefill, interleaved: the oldest admitted slot with an
        # unfinished prompt absorbs one fixed-shape chunk per tick
        pf = [(b, s) for b, s in enumerate(self._slots)
              if s is not None and s.n_prefilled < len(s.prompt)]
        if pf:
            b, slot = pf[0]
            CK = self.prefill_chunk
            chunk = slot.prompt[slot.n_prefilled:slot.n_prefilled + CK]
            toks = np.zeros((1, CK), np.int32)
            toks[0, :len(chunk)] = chunk
            logits, self.cache = self._prefill(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.page_table[b:b + 1]),
                jnp.asarray([slot.length], np.int32),
                jnp.asarray([len(chunk)], np.int32))
            slot.n_prefilled += len(chunk)
            slot.length += len(chunk)
            if slot.n_prefilled == len(slot.prompt):
                self._emit(b, self._sample(np.asarray(logits)[0], slot),
                           events)

        # one decode tick across every slot with a pending token
        tokens = np.zeros((self.n_slots, 1), np.int32)
        offsets = np.zeros((self.n_slots,), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        live = []
        for b, s in enumerate(self._slots):
            if s is not None and s.last_token is not None:
                tokens[b, 0] = s.last_token
                offsets[b] = s.length
                n_valid[b] = 1
                live.append(b)
        if live:
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(self.page_table), jnp.asarray(offsets),
                jnp.asarray(n_valid))
            lg = np.asarray(logits)
            for b in live:
                slot = self._slots[b]
                slot.length += 1
                slot.last_token = None
                self._emit(b, self._sample(lg[b], slot), events)
        return events

    def stream(self, prompts: List[List[int]], max_new: int = 32,
               stop_token: Optional[int] = None) -> Iterator[StreamEvent]:
        """Submit ``prompts`` and yield events until the engine drains."""
        for p in prompts:
            self.submit(p, max_new, stop_token)
        while self.pending:
            yield from self.step()

    def generate(self, prompts: List[List[int]], max_new: int = 32,
                 stop_token: Optional[int] = None) -> List[List[int]]:
        """Drain helper with the fixed-slot engine's signature."""
        rids = [self.submit(p, max_new, stop_token) for p in prompts]
        outs = {r: [] for r in rids}
        while self.pending:
            for ev in self.step():
                if ev.token is not None and ev.rid in outs:
                    outs[ev.rid].append(ev.token)
        return [outs[r] for r in rids]
