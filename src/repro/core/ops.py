"""Public RAPID arithmetic API used by the model zoo and applications.

Execution backends
------------------
Every approximate op routes through the backend registry in
:mod:`repro.core.backend`; the available built-ins are:

  * ``jnp``              — a chunked pure-jnp formulation (bitcast +
                           integer add + 256-gather + reduce).  This is
                           what the pjit/GSPMD partitioner sees for the
                           multi-pod dry-run, and the oracle the Pallas
                           kernels are tested against.
  * ``pallas``           — the TPU kernel in ``repro.kernels.log_matmul``
                           (VMEM tiled, grid-pipelined).
  * ``pallas-interpret`` — the same kernel under the Pallas interpreter
                           (CPU debugging / backend-parity tests).

Backend selection is one function (``backend.resolve_backend_name``)
with strict precedence:

  1. explicit ``backend=`` argument at the call site,
  2. the ``RAPID_BACKEND`` environment variable,
  3. the process default set via ``backend.set_default_backend``,
  4. hardware autodetect — ``pallas`` on TPU, ``jnp`` elsewhere.

``backend=None`` (or ``"auto"``) at any call site defers down the list,
so models/configs can stay backend-agnostic and the launcher (or an env
var in CI) picks the execution path.  The hardware level is
manual-mesh-aware: on a multi-device TPU it answers ``jnp`` for
pjit-visible (global-view) call sites but ``pallas`` when the op is
traced inside a ``shard_map`` body (``repro.compat.in_shard_map``),
where shapes are per-shard and the per-device kernel is legal — this is
how the EP/TP paths in ``models/moe.py`` run the kernels on local
shards.  Resolution happens at trace time of the call site, so the same
pinned ``backend.AUTO_HW`` entry can route one way under pjit and the
other inside a manual region of the same program.

Divider registry entries
------------------------
Every divide routes through one of three registry families (all with
``jnp`` / ``pallas`` / ``pallas-interpret`` implementations, bit-exact
between ``jnp`` and ``pallas-interpret``):

  * ``div``         — elementwise ``a / b`` (:func:`qdiv`): the online-
                      softmax combine, whose denominator comes from the
                      blockwise/flash-decode scan;
  * ``softmax_div`` — fused softmax combine (:func:`qsoftmax_div`):
                      ``e / max(sum(e, -1), floor)`` with the row-sum
                      reduction and the RAPID divide in one VMEM pass;
  * ``rms_div``     — fused rms normalize (:func:`qrms_div`):
                      ``x / sqrt(mean(x^2, -1) + eps)`` likewise fused.

On the ``pallas`` backend these are the ``repro.kernels.fused_div``
kernels, so a decode softmax or model-zoo norm is one kernel launch
instead of separate reduce / sqrt / divide round-trips through HBM.
The canonical denominator semantics (reduction over the 128-lane-padded
row) live in ``repro.kernels.fused_div.ref`` and are shared verbatim by
the jnp oracle and the kernel bodies.

Batched operation
-----------------
``qmatmul`` contracts the last dim of ``x`` with the first dim of ``w``
through a single reshaped 2-D code path: ``x`` may carry arbitrary
leading batch dims and ``w`` arbitrary *trailing* output dims (e.g. a
``(K, H, D)`` attention projection).  ``qmatmul_batched`` additionally
vmaps shared *leading* batch dims on both operands (e.g. per-expert MoE
weights ``(E, K, N)`` against ``(E, C, K)`` token buffers).

Fused epilogue menu
-------------------
``bias``, ``activation``, ``residual`` and a full ``epilogue`` spec
(:class:`repro.core.backend.Epilogue`) are fused into the matmul
epilogue on every backend: any composition drawn from ``{bias,
activation, residual-add, rms-normalize, softmax-combine}``, i.e. a
whole transformer block tail ``norm(activation(x @ w + b) + residual)``.
The jnp path applies the canonical expression on the scan accumulator;
the Pallas kernel applies the *same* expression
(``backend.apply_epilogue_tile``) to the output tile on its last K-grid
visit while it is still resident in VMEM — the normalization epilogues
reuse the fused divider kernels' lane-padded denominator semantics
(``repro.kernels.fused_div.ref``) with the RAPID approximate divider.
``Epilogue.keep_prenorm`` additionally returns the pre-norm value (the
residual stream a pre-norm block carries forward) from the same pass.

Gradients: RAPID forward ops are near-unbiased (paper SS IV-A, SS V-B), so
training uses straight-through exact gradients (standard QAT practice);
the epilogue backward differentiates the *exact* composition (activation
at the exact pre-activation value, norm as the ideal quotient).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend as be

__all__ = [
    "qmatmul",
    "qmatmul_batched",
    "qeinsum_mk_kn",
    "exact_einsum",
    "qdiv",
    "qsoftmax_div",
    "qrms_div",
    "qdecode_attn",
    "approx_softmax",
    "approx_rms_normalize",
    "approx_mean",
]


def qmatmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scheme: Optional[str] = None,
    chunk: int = 64,
    backend: Optional[str] = None,
    *,
    bias: Optional[jnp.ndarray] = None,
    activation: Optional[str] = None,
    residual: Optional[jnp.ndarray] = None,
    epilogue: Optional[be.Epilogue] = None,
):
    """Contract the last dim of ``x`` with the first dim of ``w``.

    ``scheme=None`` (or "exact") is the accurate MXU path; any RAPID/
    Mitchell scheme name routes through the logarithmic multiplier on the
    backend selected by ``backend`` (see module docstring for the
    resolution order).  Output dtype follows ``x``; RAPID internals are
    f32.

    Epilogue menu: ``bias`` (shape ``w.shape[1:]``), ``activation``
    (sugar for ``Epilogue(activation=...)``), ``residual`` (the output's
    shape) and a full ``epilogue`` spec are fused into the matmul
    epilogue as ``norm(activation(out + bias) + residual)``.  The norm
    stages reduce over the output's last dim and therefore require a 2-D
    ``w``; with ``epilogue.keep_prenorm`` the result is the pair
    ``(tail, pre_norm)``.

    The exact path is a *plain* dot (fully transparent to autodiff and
    remat policies; its norm stage routes through the registry divider
    ops, so an exact matmul can still carry a RAPID-divider norm tail);
    the approximate path is a custom_vjp with straight-through exact
    gradients.
    """
    ep = be.as_epilogue(epilogue, activation)
    if bias is not None and bias.shape != w.shape[1:]:
        raise ValueError(f"bias shape {bias.shape} != w.shape[1:] {w.shape[1:]}")
    if ep.norm is not None and w.ndim != 2:
        raise ValueError(
            f"norm epilogues reduce over the output's last dim and need a "
            f"2-D weight; got w.shape={w.shape}")
    out_shape = x.shape[:-1] + w.shape[1:]
    if residual is not None and residual.shape != out_shape:
        raise ValueError(
            f"residual shape {residual.shape} != output shape {out_shape}")
    if scheme in (None, "exact"):
        out = jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # same epilogue semantics as the approximate backends: the whole
        # menu in f32, then cast to the input dtype
        if bias is not None:
            out = out + bias
        if ep.activation is not None:
            out = be.ACTIVATIONS[ep.activation](out)
        if residual is not None:
            out = out + residual.astype(jnp.float32)
        pre = out
        if ep.norm == "softmax":
            out = qsoftmax_div(out, ep.div_scheme, backend, floor=ep.floor)
        elif ep.norm == "rms":
            out = qrms_div(out, ep.eps, ep.div_scheme, backend)
        if ep.keep_prenorm:
            return out.astype(x.dtype), pre.astype(x.dtype)
        return out.astype(x.dtype)
    backend = be.resolve_backend_name(backend)
    return _qmatmul_approx(x, w, bias, residual, scheme, chunk, backend, ep)


def _exact_tail(x, w, bias, residual, ep: be.Epilogue):
    """The ideal (exact-arithmetic) composition the backward pass
    differentiates — straight-through gradients for the whole menu."""
    k = x.shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    w2 = w.reshape(k, -1).astype(jnp.float32)
    z = jnp.dot(x2, w2)
    if bias is not None:
        z = z + bias.astype(jnp.float32).reshape(-1)[None, :]
    if ep.activation is not None:
        z = be.ACTIVATIONS[ep.activation](z)
    if residual is not None:
        z = z + residual.astype(jnp.float32).reshape(z.shape)
    pre = z
    if ep.norm == "softmax":
        z = z / jnp.maximum(jnp.sum(z, axis=-1, keepdims=True), ep.floor)
    elif ep.norm == "rms":
        z = z / jnp.sqrt(jnp.mean(jnp.square(z), axis=-1, keepdims=True)
                         + ep.eps)
    out_shape = x.shape[:-1] + w.shape[1:]
    if ep.keep_prenorm:
        return z.reshape(out_shape), pre.reshape(out_shape)
    return z.reshape(out_shape)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _qmatmul_approx(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray],
    residual: Optional[jnp.ndarray],
    scheme: str,
    chunk: int = 64,
    backend: str = "jnp",
    ep: be.Epilogue = be.Epilogue(),
):
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    w2 = w.reshape(k, -1).astype(jnp.float32)
    b2 = None if bias is None else bias.astype(jnp.float32).reshape(-1)
    r2 = (None if residual is None
          else residual.astype(jnp.float32).reshape(x2.shape[0], w2.shape[1]))
    out = be.matmul(x2, w2, scheme, backend=backend, chunk=chunk,
                    bias=b2, residual=r2, epilogue=ep)
    shape = (*lead, *w.shape[1:])
    if ep.keep_prenorm:
        tail, pre = out
        return tail.reshape(shape).astype(x.dtype), \
            pre.reshape(shape).astype(x.dtype)
    return out.reshape(shape).astype(x.dtype)


def _qmatmul_fwd(x, w, bias, residual, scheme, chunk, backend, ep):
    out = _qmatmul_approx(x, w, bias, residual, scheme, chunk, backend, ep)
    return out, (x, w, bias, residual)


def _qmatmul_bwd(scheme, chunk, backend, ep, res, g):
    # straight-through: differentiate the exact composition (activation
    # at the exact pre-activation value, norm as the ideal quotient)
    x, w, bias, residual = res
    _, pullback = jax.vjp(
        lambda x, w, bias, residual: _exact_tail(x, w, bias, residual, ep),
        x, w, bias, residual)
    gf = jax.tree.map(lambda t: t.astype(jnp.float32), g)
    dx, dw, db, dr = pullback(gf)
    dx = dx.astype(x.dtype)
    dw = dw.astype(w.dtype)
    db = None if bias is None else db.astype(bias.dtype)
    dr = None if residual is None else dr.astype(residual.dtype)
    return dx, dw, db, dr


_qmatmul_approx.defvjp(_qmatmul_fwd, _qmatmul_bwd)


def qmatmul_batched(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scheme: Optional[str] = None,
    chunk: int = 64,
    backend: Optional[str] = None,
    *,
    bias: Optional[jnp.ndarray] = None,
    activation: Optional[str] = None,
) -> jnp.ndarray:
    """Batched matmul with *shared* leading batch dims on ``x`` and ``w``.

    ``x``: ``[*B, M, K]``; ``w``: ``[*B, K, N]`` -> ``[*B, M, N]`` — the
    per-expert MoE contraction.  Implemented as vmap over :func:`qmatmul`
    so every batch element reuses the same registry-dispatched 2-D path
    (and the same straight-through custom_vjp).  ``bias`` may be shared
    (shape ``w.shape[nb:][1:]``, broadcast over the batch) or per-batch
    (shape ``w.shape[:nb] + w.shape[nb+1:]``).
    """
    if w.ndim == 2:
        return qmatmul(x, w, scheme, chunk, backend,
                       bias=bias, activation=activation)
    nb = w.ndim - 2
    if x.shape[:nb] != w.shape[:nb]:
        raise ValueError(f"batch dims mismatch: {x.shape[:nb]} vs {w.shape[:nb]}")
    bias_axis = None
    if bias is not None:
        if bias.shape == w.shape[nb + 1:]:
            bias_axis = None  # shared across the batch
        elif bias.shape == w.shape[:nb] + w.shape[nb + 1:]:
            bias_axis = 0
        else:
            raise ValueError(
                f"bias shape {bias.shape} must be {w.shape[nb + 1:]} (shared) "
                f"or {w.shape[:nb] + w.shape[nb + 1:]} (per-batch)")
    fn = lambda xb, wb, bb: qmatmul(  # noqa: E731
        xb, wb, scheme, chunk, backend, bias=bb, activation=activation)
    for _ in range(nb):
        fn = jax.vmap(fn, in_axes=(0, 0, bias_axis))
    return fn(x, w, bias)


def qeinsum_mk_kn(x, w, scheme=None, **kw):
    """Alias kept for symmetry with the kernels' ref.py naming."""
    return qmatmul(x, w, scheme, **kw)


def exact_einsum(spec: str, *operands):
    """Declared-exact contraction — the audited alternative to a raw
    ``jnp.einsum`` in model/app code.

    The paper approximates *weight* matmuls and divides; activation-
    activation contractions with data-dependent operand layouts (the
    attention score/value einsums) intentionally stay on the exact MXU
    path.  Routing them through this wrapper (instead of calling
    ``jnp.einsum`` at the site) does two things for the dispatch
    auditor: the AST lint's RPD001 no longer fires (core/ is the
    declared-exact zone), and the traced ``dot_general``'s innermost
    user frame lands in this file, so the jaxpr census counts it as
    registry-accounted rather than an escape.
    """
    return jnp.einsum(spec, *operands)


def qdiv(
    a: jnp.ndarray,
    b: jnp.ndarray,
    scheme: str,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Registry-routed elementwise approximate divide (broadcasting ok).

    The backend resolves here — once, before the custom_jvp — so a
    backend pinned at engine/trainstep build time cannot be re-resolved
    from env/default inside a later trace.  Straight-through gradients:
    the backward pass differentiates the ideal quotient.
    """
    backend = be.resolve_backend_name(backend)
    return _qdiv_approx(a, b, scheme, backend)


@partial(jax.custom_jvp, nondiff_argnums=(2, 3))
def _qdiv_approx(a, b, scheme, backend):
    return be.div(a, b, scheme, backend=backend)


@_qdiv_approx.defjvp
def _qdiv_jvp(scheme, backend, primals, tangents):
    a, b = primals
    da, db = tangents
    out = _qdiv_approx(a, b, scheme, backend)
    return out, (da * b - a * db) / (b * b)


def qsoftmax_div(
    e: jnp.ndarray,
    scheme: Optional[str],
    backend: Optional[str] = None,
    *,
    floor: float = be.SOFTMAX_FLOOR,
    axis: int = -1,
) -> jnp.ndarray:
    """Fused softmax combine: ``e / max(sum(e, axis), floor)``.

    ``e`` holds non-negative exp-weights; on the ``pallas`` backend the
    row-sum reduction and the RAPID divide run in one VMEM-resident
    kernel pass (registry family ``softmax_div``).  The floor keeps
    fully-masked rows (all weights zero) from dividing by zero.
    """
    if axis not in (-1, e.ndim - 1):
        out = qsoftmax_div(jnp.moveaxis(e, axis, -1), scheme, backend,
                           floor=floor)
        return jnp.moveaxis(out, -1, axis)
    if scheme in (None, "exact"):
        ef = e.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(ef, axis=-1, keepdims=True), floor)
        return (ef / denom).astype(e.dtype)
    backend = be.resolve_backend_name(backend)
    return _qsoftmax_div_approx(e, scheme, backend, float(floor))


@partial(jax.custom_jvp, nondiff_argnums=(1, 2, 3))
def _qsoftmax_div_approx(e, scheme, backend, floor):
    out = be.softmax_div(e.astype(jnp.float32), scheme, backend=backend,
                         floor=floor)
    return out.astype(e.dtype)


@_qsoftmax_div_approx.defjvp
def _qsoftmax_div_jvp(scheme, backend, floor, primals, tangents):
    # straight-through: differentiate the ideal fused expression
    (e,), (de,) = primals, tangents
    exact = lambda e: e / jnp.maximum(  # noqa: E731
        jnp.sum(e, axis=-1, keepdims=True), floor)
    _, tangent = jax.jvp(exact, (e,), (de,))
    return _qsoftmax_div_approx(e, scheme, backend, floor), tangent


def qrms_div(
    x: jnp.ndarray,
    eps: float,
    scheme: Optional[str],
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Fused rms normalize: ``x / sqrt(mean(x^2, -1) + eps)``.

    On the ``pallas`` backend the mean-of-squares reduction, the sqrt
    and the RAPID divide run in one VMEM-resident kernel pass (registry
    family ``rms_div``) — a model-zoo norm stops round-tripping HBM
    between its reduction and its divide.
    """
    if scheme in (None, "exact"):
        xf = x.astype(jnp.float32)
        denom = jnp.sqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
        return (xf / denom).astype(x.dtype)
    backend = be.resolve_backend_name(backend)
    return _qrms_div_approx(x, float(eps), scheme, backend)


@partial(jax.custom_jvp, nondiff_argnums=(1, 2, 3))
def _qrms_div_approx(x, eps, scheme, backend):
    out = be.rms_div(x.astype(jnp.float32), eps, scheme, backend=backend)
    return out.astype(x.dtype)


@_qrms_div_approx.defjvp
def _qrms_div_jvp(eps, scheme, backend, primals, tangents):
    (x,), (dx,) = primals, tangents
    exact = lambda x: x / jnp.sqrt(  # noqa: E731
        jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    _, tangent = jax.jvp(exact, (x,), (dx,))
    return _qrms_div_approx(x, eps, scheme, backend), tangent


def qdecode_attn(
    qf: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    slot_positions: jnp.ndarray,
    pos,
    window: int,
    scheme: Optional[str],
    backend: Optional[str] = None,
    *,
    floor: float = be.SOFTMAX_FLOOR,
) -> jnp.ndarray:
    """Fused single-token decode attention (registry family
    ``decode_attn``).

    qf: [B, KV, G, hd] *pre-scaled* f32 queries; caches: [B, C, KV, hd];
    slot_positions: [B, C] absolute positions (MAX_INT = empty slot);
    ``pos`` scalar or [B]-vector of current positions.  On the pallas
    backends the score matmul, online softmax stats, value matmul and
    the floored RAPID combine divide run as one flash kernel whose
    intermediates never visit HBM; the jnp path is the exact-stats
    reference with the same combine semantics.  Decode is inference-
    only, so no custom gradient wrapper (the approximate divide inside
    carries its own straight-through rule).  Returns [B, KV, G, hd] f32.
    """
    backend = be.resolve_backend_name(backend)
    return be.decode_attn(qf, k_cache, v_cache, slot_positions, pos,
                          window, scheme, backend=backend, floor=floor)


def approx_softmax(
    x: jnp.ndarray, axis: int = -1, div_scheme: Optional[str] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Softmax whose normalisation uses the RAPID divider.

    The exp() stays exact (the paper approximates only mul/div); the
    denominator division — the op that dominates softmax cost on the
    FPGA datapath — is replaced by the logarithmic divider, fused with
    its row-sum via the registry's ``softmax_div`` family.
    """
    x_max = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = jnp.exp(x - x_max)
    if div_scheme in (None, "exact"):
        return e / jnp.sum(e, axis=axis, keepdims=True)
    return qsoftmax_div(e, div_scheme, backend, axis=axis).astype(x.dtype)


def approx_rms_normalize(
    x: jnp.ndarray, eps: float = 1e-6, div_scheme: Optional[str] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """x / sqrt(mean(x^2) + eps) with an optional RAPID divider."""
    return qrms_div(x, eps, div_scheme, backend)


def approx_mean(
    x: jnp.ndarray, axis: int = -1, div_scheme: Optional[str] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Mean whose final divide uses the RAPID divider (used by the apps).

    Both paths return ``x.dtype`` so exact/approx parity checks compare
    like dtypes (the exact path used to leak float32).
    """
    s = jnp.sum(x, axis=axis)
    n = jnp.float32(x.shape[axis])
    if div_scheme in (None, "exact"):
        return (s / n).astype(x.dtype)
    return qdiv(s.astype(jnp.float32), n, div_scheme, backend).astype(x.dtype)
