"""Public RAPID arithmetic API used by the model zoo and applications.

Two execution paths exist for every op:

  * ``jnp``    — a chunked pure-jnp formulation (bitcast + integer add +
                 256-gather + reduce).  This is what the pjit/GSPMD
                 partitioner sees for the multi-pod dry-run, and the oracle
                 the Pallas kernels are tested against.
  * ``pallas`` — the TPU kernel in ``repro.kernels.log_matmul`` (VMEM
                 tiled, grid-pipelined).  Selected via ``backend="pallas"``
                 by the launcher when running on real TPU.

Gradients: RAPID forward ops are near-unbiased (paper SS IV-A, SS V-B), so
training uses straight-through exact gradients (standard QAT practice).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa

__all__ = [
    "qmatmul",
    "qeinsum_mk_kn",
    "approx_softmax",
    "approx_rms_normalize",
    "approx_mean",
]


def _log_matmul_jnp(
    x: jnp.ndarray, w: jnp.ndarray, lut: jnp.ndarray, chunk: int
) -> jnp.ndarray:
    """RAPID matmul x[M,K] @ w[K,N] via K-chunked log-domain products."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    chunk = min(chunk, k)
    pad = (-k) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    steps = (k + pad) // chunk
    xs = x.reshape(m, steps, chunk).transpose(1, 0, 2)  # [steps, M, C]
    ws = w.reshape(steps, chunk, n)  # [steps, C, N]

    def body(acc, operands):
        xc, wc = operands
        prod = fa.log_mul_f32(xc[:, :, None], wc[None, :, :], lut)  # [M,C,N]
        return acc + prod.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xs, ws))
    return acc


def qmatmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scheme: Optional[str] = None,
    chunk: int = 64,
    backend: str = "jnp",
) -> jnp.ndarray:
    """Contract the last dim of ``x`` with the first dim of ``w``.

    ``scheme=None`` (or "exact") is the accurate MXU path; any RAPID/
    Mitchell scheme name routes through the logarithmic multiplier.
    Output dtype follows ``x``; RAPID internals are f32.

    The exact path is a *plain* dot (fully transparent to autodiff and
    remat policies); the approximate path is a custom_vjp with straight-
    through exact gradients.
    """
    if scheme in (None, "exact"):
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
    return _qmatmul_approx(x, w, scheme, chunk, backend)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _qmatmul_approx(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scheme: str,
    chunk: int = 64,
    backend: str = "jnp",
) -> jnp.ndarray:
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    w2 = w.reshape(k, -1).astype(jnp.float32)
    if backend == "pallas":
        from repro.kernels.log_matmul.ops import log_matmul

        out = log_matmul(x2, w2, scheme)
    else:
        lut = jnp.asarray(fa.mul_lut(scheme))
        out = _log_matmul_jnp(x2, w2, lut, chunk)
    return out.reshape(*lead, *w.shape[1:]).astype(x.dtype)


def _qmatmul_fwd(x, w, scheme, chunk, backend):
    return _qmatmul_approx(x, w, scheme, chunk, backend), (x, w)


def _qmatmul_bwd(scheme, chunk, backend, res, g):
    x, w = res
    # straight-through: exact transposed contractions for the cotangents
    g2 = g.reshape(-1, w.shape[1:][-1] if w.ndim > 1 else 1)
    x2 = x.reshape(-1, x.shape[-1])
    dx = jnp.dot(g2, w.reshape(x.shape[-1], -1).T).reshape(x.shape)
    dw = jnp.dot(x2.T, g2).reshape(w.shape)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_qmatmul_approx.defvjp(_qmatmul_fwd, _qmatmul_bwd)


def qeinsum_mk_kn(x, w, scheme=None, **kw):
    """Alias kept for symmetry with the kernels' ref.py naming."""
    return qmatmul(x, w, scheme, **kw)


def approx_softmax(
    x: jnp.ndarray, axis: int = -1, div_scheme: Optional[str] = None
) -> jnp.ndarray:
    """Softmax whose normalisation uses the RAPID divider.

    The exp() stays exact (the paper approximates only mul/div); the
    denominator division — the op that dominates softmax cost on the
    FPGA datapath — is replaced by the logarithmic divider.
    """
    x_max = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = jnp.exp(x - x_max)
    denom = jnp.sum(e, axis=axis, keepdims=True)
    if div_scheme in (None, "exact"):
        return e / denom
    return fa.approx_div(e, denom, div_scheme).astype(x.dtype)


def approx_rms_normalize(
    x: jnp.ndarray, eps: float = 1e-6, div_scheme: Optional[str] = None
) -> jnp.ndarray:
    """x / sqrt(mean(x^2) + eps) with an optional RAPID divider."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    denom = jnp.sqrt(var + eps)
    if div_scheme in (None, "exact"):
        return (x.astype(jnp.float32) / denom).astype(x.dtype)
    return fa.approx_div(x.astype(jnp.float32), denom, div_scheme).astype(x.dtype)


def approx_mean(
    x: jnp.ndarray, axis: int = -1, div_scheme: Optional[str] = None
) -> jnp.ndarray:
    """Mean whose final divide uses the RAPID divider (used by the apps)."""
    s = jnp.sum(x, axis=axis)
    n = jnp.float32(x.shape[axis])
    if div_scheme in (None, "exact"):
        return s / n
    return fa.approx_div(s.astype(jnp.float32), n, div_scheme).astype(x.dtype)
