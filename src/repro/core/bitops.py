"""Integer bit-manipulation primitives shared by the RAPID arithmetic core.

Everything here is branch-free and vectorised so it lowers cleanly inside
``jax.jit`` *and* inside Pallas kernel bodies (which see the same jnp ops).
A mirrored numpy implementation is provided for the offline calibration /
exhaustive-accuracy oracles, where we want uint64 headroom without enabling
jax x64 globally.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "ilog2",
    "ilog2_np",
    "popcount32",
    "smear32",
]


def smear32(v: jnp.ndarray) -> jnp.ndarray:
    """Smear the leading one of each 32-bit lane down to bit 0."""
    v = v | (v >> 1)
    v = v | (v >> 2)
    v = v | (v >> 4)
    v = v | (v >> 8)
    v = v | (v >> 16)
    return v


def popcount32(v: jnp.ndarray) -> jnp.ndarray:
    """Population count for int32/uint32 lanes (SWAR, no lookup tables)."""
    v = v - ((v >> 1) & 0x55555555)
    v = (v & 0x33333333) + ((v >> 2) & 0x33333333)
    v = (v + (v >> 4)) & 0x0F0F0F0F
    return (v * 0x01010101) >> 24


def ilog2(v: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(v)) for positive int32 lanes.

    This is the software analogue of the paper's Leading-One Detector
    (LOD): the FPGA version probes 4-bit segments with Flag-LUTs and a
    priority mux; on TPU the VPU has no clz, so we use the classic
    smear+popcount sequence (5 shifts/ors + SWAR popcount), which is the
    same O(log N) depth the segmented LOD achieves in LUT logic.
    Undefined for v <= 0 (returns -1 for v == 0).
    """
    v = v.astype(jnp.int32)
    return popcount32(smear32(v)) - 1


def ilog2_np(v: np.ndarray) -> np.ndarray:
    """Numpy mirror of :func:`ilog2` with uint64 support (for oracles)."""
    v = np.asarray(v)
    out = np.zeros(v.shape, dtype=np.int64)
    x = v.astype(np.uint64).copy()
    for shift in (1, 2, 4, 8, 16, 32):
        x |= x >> np.uint64(shift)
    # popcount on uint64
    x = x - ((x >> np.uint64(1)) & np.uint64(0x5555555555555555))
    x = (x & np.uint64(0x3333333333333333)) + (
        (x >> np.uint64(2)) & np.uint64(0x3333333333333333)
    )
    x = (x + (x >> np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    out = ((x * np.uint64(0x0101010101010101)) >> np.uint64(56)).astype(np.int64)
    return out - 1
