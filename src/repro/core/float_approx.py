"""RAPID logarithmic arithmetic on IEEE-754 floats (the TPU-native port).

The FPGA units operate on integer operands: find the leading one (k),
treat the remaining bits as a fraction x, approximate log2 as k + x, add
(subtract) in the log domain + a RAPID error coefficient, anti-log by a
shift.  An IEEE-754 float *is already* the (k, x) pair: the exponent field
is k and the mantissa field is x.  Bit-casting a positive float to an
integer therefore yields exactly Mitchell's log approximation (scaled by
2^23, biased by 127 << 23), so the whole Mitchell+RAPID pipeline becomes:

    bits(a) + bits(b) - BIAS + coeff[idx(a), idx(b)]      (multiply)
    bits(a) - bits(b) + BIAS + coeff[idx(a), idx(b)]      (divide)

where ``idx`` is the 4 MSBs of the mantissa — precisely the paper's
coefficient-selection index — and the mantissa-adder carry into the
exponent field implements the ``x1+x2 >= 1`` anti-log case for free (the
same role the ternary-adder MSB plays on the FPGA).

This path is branch-free integer add + 256-entry gather per element: pure
VPU work on TPU, no MXU, no transcendentals.  It is the building block of
the ``log_matmul`` Pallas kernel and of the elementwise approx ops used in
softmax/normalisation denominators.

Error characteristics are *identical* to the integer units for the same
scheme (the error depends only on the fraction pair), with mantissa
quantisation at 2^-23 instead of the integer fraction width.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mitchell, schemes
from repro.core.mitchell import ErrorScheme

__all__ = [
    "mul_lut",
    "div_lut",
    "mul_lut_device",
    "div_lut_device",
    "log_mul_f32",
    "log_div_f32",
    "log_recip_f32",
    "approx_mul",
    "approx_div",
]

_F32_FRAC = 23
_F32_BIAS = np.int32(127 << 23)
_F32_ABS = np.int32(0x7FFFFFFF)
_F32_SIGN = np.int32(-0x80000000)
_MIN_NORMAL = np.int32(0x00800000)
_INF_BITS = np.int32(0x7F800000)


def _lut_host(kind: str, scheme: ErrorScheme) -> np.ndarray:
    """Memoized read-only (256,) int32 host LUT at the f32 fraction width
    (shared build/cache machinery: ``repro.core.mitchell.lut_host``)."""
    assert scheme.kind == kind
    return mitchell.lut_host(scheme, _F32_FRAC)


def _lut_device(kind: str, scheme: ErrorScheme, dtype: str):
    """Memoized on-device LUT per (scheme, dtype): one upload ever
    (shared machinery: ``repro.core.mitchell.lut_device``)."""
    assert scheme.kind == kind
    return mitchell.lut_device(scheme, _F32_FRAC, dtype)


def _as_scheme(kind: str, scheme: ErrorScheme | str) -> ErrorScheme:
    if isinstance(scheme, str):
        table = schemes.MUL_SCHEMES if kind == "mul" else schemes.DIV_SCHEMES
        return table[scheme]
    return scheme


def mul_lut(scheme: ErrorScheme | str) -> np.ndarray:
    """(256,) int32 coefficient LUT for f32 multiply (host, memoized)."""
    return _lut_host("mul", _as_scheme("mul", scheme))


def div_lut(scheme: ErrorScheme | str) -> np.ndarray:
    """(256,) int32 coefficient LUT for f32 divide (host, memoized)."""
    return _lut_host("div", _as_scheme("div", scheme))


def mul_lut_device(scheme: ErrorScheme | str, dtype: str = "int32"):
    """(256,) on-device multiply LUT, memoized per (scheme, dtype)."""
    return _lut_device("mul", _as_scheme("mul", scheme), dtype)


def div_lut_device(scheme: ErrorScheme | str, dtype: str = "int32"):
    """(256,) on-device divide LUT, memoized per (scheme, dtype)."""
    return _lut_device("div", _as_scheme("div", scheme), dtype)


def _log_mul_bits(m1: jnp.ndarray, m2: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Magnitude-bits multiply in the log domain. m1, m2 >= 0 (int32)."""
    i1 = (m1 >> (_F32_FRAC - 4)) & 0xF
    i2 = (m2 >> (_F32_FRAC - 4)) & 0xF
    c = jnp.take(lut, i1 * 16 + i2)
    return m1 + m2 - _F32_BIAS + c


def _log_div_bits(m1: jnp.ndarray, m2: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    i1 = (m1 >> (_F32_FRAC - 4)) & 0xF
    i2 = (m2 >> (_F32_FRAC - 4)) & 0xF
    c = jnp.take(lut, i1 * 16 + i2)
    return m1 - m2 + _F32_BIAS + c


def _finish(sum_bits: jnp.ndarray, sign: jnp.ndarray, dead: jnp.ndarray) -> jnp.ndarray:
    """Clamp under/overflow, apply sign, zero the dead lanes, bitcast."""
    sum_bits = jnp.where(sum_bits >= _INF_BITS, _INF_BITS, sum_bits)
    sum_bits = jnp.where(sum_bits < _MIN_NORMAL, 0, sum_bits)  # flush subnormal
    sum_bits = jnp.where(dead, 0, sum_bits)
    return jax.lax.bitcast_convert_type(sum_bits | sign, jnp.float32)


def log_mul_f32(a: jnp.ndarray, b: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Elementwise RAPID approximate a*b for float32 (broadcasting ok).

    Semantics: flush-to-zero for subnormals, 0*x == 0 (including 0*inf),
    inf propagates, exponent overflow saturates to inf.
    """
    a, b = jnp.broadcast_arrays(a, b)
    ba = jax.lax.bitcast_convert_type(a, jnp.int32)
    bb = jax.lax.bitcast_convert_type(b, jnp.int32)
    sign = (ba ^ bb) & _F32_SIGN
    m1, m2 = ba & _F32_ABS, bb & _F32_ABS
    s = _log_mul_bits(m1, m2, lut)
    # int32 wrap detection: (m1 - BIAS) + m2 overflowed iff both halves were
    # non-negative yet the sum is negative -> real exponent way past inf.
    half = m1 - _F32_BIAS
    wrapped = (half >= 0) & (s < 0)
    s = jnp.where(wrapped | (m1 >= _INF_BITS) | (m2 >= _INF_BITS), _INF_BITS, s)
    dead = (m1 < _MIN_NORMAL) | (m2 < _MIN_NORMAL)  # 0 * x == 0
    return _finish(s, sign, dead)


def log_div_f32(a: jnp.ndarray, b: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Elementwise RAPID approximate a/b for float32. b==0 -> +-inf."""
    a, b = jnp.broadcast_arrays(a, b)
    ba = jax.lax.bitcast_convert_type(a, jnp.int32)
    bb = jax.lax.bitcast_convert_type(b, jnp.int32)
    sign = (ba ^ bb) & _F32_SIGN
    m1, m2 = ba & _F32_ABS, bb & _F32_ABS
    s = _log_div_bits(m1, m2, lut)
    diff = m1 - m2
    wrapped = (diff >= 0) & (s < 0)  # huge / tiny past inf
    s = jnp.where(wrapped | (m1 >= _INF_BITS), _INF_BITS, s)
    s = jnp.where(m2 < _MIN_NORMAL, _INF_BITS, s)  # x / 0
    dead = m1 < _MIN_NORMAL  # 0 / x == 0
    return _finish(s, sign, dead)


def log_recip_f32(b: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Approximate 1/b (division with dividend fraction fixed at zero)."""
    return log_div_f32(jnp.ones_like(b), b, lut)


# --------------------------------------------------------------------------
# Public elementwise ops with scheme names + gradient support.
#
# The ops are near-unbiased (paper SS IV-A), so we give them straight-
# through exact gradients: the forward pass carries the approximation, the
# backward pass differentiates the *ideal* product/quotient.  This mirrors
# how quantised training treats non-differentiable rounding, and is what
# makes RAPID usable inside training graphs, not just inference.
# --------------------------------------------------------------------------

@partial(jax.custom_jvp, nondiff_argnums=(2,))
def approx_mul(a: jnp.ndarray, b: jnp.ndarray, scheme: str = "rapid10") -> jnp.ndarray:
    orig = a.dtype
    lut = mul_lut_device(scheme)
    out = log_mul_f32(a.astype(jnp.float32), b.astype(jnp.float32), lut)
    return out.astype(orig)


@approx_mul.defjvp
def _approx_mul_jvp(scheme, primals, tangents):
    a, b = primals
    da, db = tangents
    return approx_mul(a, b, scheme), a * db + b * da


@partial(jax.custom_jvp, nondiff_argnums=(2,))
def approx_div(a: jnp.ndarray, b: jnp.ndarray, scheme: str = "rapid9") -> jnp.ndarray:
    orig = a.dtype
    lut = div_lut_device(scheme)
    out = log_div_f32(a.astype(jnp.float32), b.astype(jnp.float32), lut)
    return out.astype(orig)


@approx_div.defjvp
def _approx_div_jvp(scheme, primals, tangents):
    a, b = primals
    da, db = tangents
    return approx_div(a, b, scheme), (da * b - a * db) / (b * b)
