"""Bit-exact Mitchell logarithmic multiplier / divider with RAPID error reduction.

This module is the *algorithmic ground truth* of the repo.  It provides:

  * a numpy oracle (`mitchell_mul_np`, `mitchell_div_np`) with uint64
    headroom, used for exhaustive 8-bit / sampled 16-bit / Monte-Carlo
    32-bit accuracy characterisation (paper Table III), and
  * a jit-safe jnp implementation (`mitchell_mul`, `mitchell_div`) for
    8/16-bit operands (uint32 intermediates), mirrored by the Pallas
    kernels in ``repro.kernels``.

Algorithm (paper Eq. 1-7).  For N-bit unsigned A with leading one at k:
``A = 2^k (1 + x)`` with fraction ``x in [0,1)``.  Mitchell approximates
``log2(A) ~= k + x``.  The product log is the sum of the two parts; the
anti-log is a shift.  RAPID adds an error-reduction coefficient ``c``
*inside the same fraction addition* (the FPGA version uses the 6-LUT +
carry-chain ternary adder; here it is simply a third addend), selected
from a (16,16) lookup table indexed by the 4 MSBs of each fraction.

All shifts truncate (match the hardware barrel shifter).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import ilog2, ilog2_np

__all__ = [
    "ErrorScheme",
    "MITCHELL_MUL",
    "MITCHELL_DIV",
    "lut_host",
    "lut_device",
    "mitchell_mul_np",
    "mitchell_div_np",
    "mitchell_mul",
    "mitchell_div",
]


@dataclass(frozen=True)
class ErrorScheme:
    """A RAPID error-reduction scheme.

    ``assign`` maps the (i1, i2) cell — the 4 MSBs of each operand's
    fraction — to a group id; ``coeffs`` holds one signed coefficient per
    group, as a fraction of the fixed-point scale (i.e. in units of the
    operand fraction, c in (-0.5, 0.5)).
    """

    name: str
    kind: Literal["mul", "div"]
    assign: tuple  # (16,16) nested tuple of ints -> group id
    coeffs: tuple  # (G,) floats

    @property
    def n_coeffs(self) -> int:
        return len(self.coeffs)

    def lut(self, frac_bits: int) -> np.ndarray:
        """Flat (256,) int64 LUT of fixed-point coefficients at ``frac_bits``."""
        a = np.asarray(self.assign, dtype=np.int64).reshape(16, 16)
        c = np.asarray(self.coeffs, dtype=np.float64)
        return np.round(c[a] * (1 << frac_bits)).astype(np.int64).reshape(-1)


# Plain Mitchell == the degenerate single-coefficient-zero scheme.
_ZERO_ASSIGN = tuple(tuple(0 for _ in range(16)) for _ in range(16))
MITCHELL_MUL = ErrorScheme("mitchell", "mul", _ZERO_ASSIGN, (0.0,))
MITCHELL_DIV = ErrorScheme("mitchell", "div", _ZERO_ASSIGN, (0.0,))


# --------------------------------------------------------------------------
# the single memoized LUT build/upload path — every consumer (float_approx
# at the f32 fraction width, the integer kernels at theirs) delegates
# here so there is exactly one cache implementation in the repo.
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def lut_host(scheme: ErrorScheme, frac_bits: int) -> np.ndarray:
    """Memoized read-only (256,) int32 host LUT per (scheme, width).

    Building the table walks the 16x16 assignment grid in python/numpy —
    cheap once, but hot paths used to redo it per call.  Read-only
    because the array is shared across callers.
    """
    lut = scheme.lut(frac_bits).astype(np.int32)
    lut.setflags(write=False)
    return lut


@lru_cache(maxsize=None)
def lut_device(scheme: ErrorScheme, frac_bits: int, dtype: str = "int32"):
    """Memoized on-device LUT per (scheme, width, dtype): one upload ever.

    ensure_compile_time_eval keeps the cached value a *concrete* device
    array even when the first call happens inside a jit trace — without
    it the cache would capture (and leak) a tracer.
    """
    with jax.ensure_compile_time_eval():
        return jnp.asarray(lut_host(scheme, frac_bits), jnp.dtype(dtype))


# --------------------------------------------------------------------------
# numpy oracle (uint64 headroom; exact for operands up to 32 bits)
# --------------------------------------------------------------------------

def _frac_align_np(v: np.ndarray, k: np.ndarray, frac_bits: int) -> np.ndarray:
    """Fraction bits of v (below the leading one), left-aligned to frac_bits."""
    frac = v.astype(np.int64) - (np.int64(1) << k)
    return frac << (frac_bits - k)


def mitchell_mul_np(
    a: np.ndarray,
    b: np.ndarray,
    scheme: ErrorScheme = MITCHELL_MUL,
    n_bits: int = 16,
    quantize: bool = True,
) -> np.ndarray:
    """Approximate a*b for unsigned operands (< 2**n_bits). Exact zeros.

    ``quantize=True`` matches the hardware barrel shifter (integer output,
    truncating).  ``quantize=False`` returns the full fixed-point value as
    float64 — this is the convention under which the paper's Table III
    accuracy numbers are reported (the output fraction bits are part of
    the datapath; error metrics are over the real-valued result).
    """
    assert scheme.kind == "mul"
    a = np.asarray(a, dtype=np.uint64).astype(np.int64)
    b = np.asarray(b, dtype=np.uint64).astype(np.int64)
    F = n_bits - 1
    lut = scheme.lut(F)

    k1 = ilog2_np(np.maximum(a, 1))
    k2 = ilog2_np(np.maximum(b, 1))
    f1 = _frac_align_np(a, k1, F)
    f2 = _frac_align_np(b, k2, F)
    i1 = (f1 >> (F - 4)) & 0xF
    i2 = (f2 >> (F - 4)) & 0xF
    c = lut[i1 * 16 + i2]

    s = f1 + f2 + c
    ksum = k1 + k2
    one = np.int64(1) << F
    # branch: s < 2^F  ->  2^ksum * (1 + s/2^F) ; else 2^(ksum+1) * (s/2^F)
    carry = s >= one
    mant = np.where(carry, s, s + one).astype(np.uint64)  # in [2^F, 2.25*2^F)
    shift = ksum + carry.astype(np.int64) - F
    # guard negative coefficients driving s below 0 in near-zero-fraction cells
    mant = np.maximum(mant.astype(np.int64), 0).astype(np.uint64)
    if not quantize:
        val = mant.astype(np.float64) * np.exp2(shift.astype(np.float64))
        return np.where((a == 0) | (b == 0), 0.0, val)
    pos = np.maximum(shift, 0).astype(np.uint64)
    neg = np.maximum(-shift, 0).astype(np.uint64)
    res = (mant << pos) >> neg
    return np.where((a == 0) | (b == 0), np.uint64(0), res)


def mitchell_div_np(
    a: np.ndarray,
    b: np.ndarray,
    scheme: ErrorScheme = MITCHELL_DIV,
    n_bits: int = 16,
    quantize: bool = True,
) -> np.ndarray:
    """Approximate a/b (truncated) for unsigned a < 2**(2*n_bits), b < 2**n_bits.

    Follows the paper's 2N-by-N divider; b == 0 returns the saturated max.
    ``quantize=False`` returns the full fixed-point quotient (float64) —
    the convention of the paper's accuracy tables.
    """
    assert scheme.kind == "div"
    a = np.asarray(a, dtype=np.uint64).astype(np.int64)
    b = np.asarray(b, dtype=np.uint64).astype(np.int64)
    F = 2 * n_bits - 1
    lut = scheme.lut(F)

    k1 = ilog2_np(np.maximum(a, 1))
    k2 = ilog2_np(np.maximum(b, 1))
    f1 = _frac_align_np(a, k1, F)
    f2 = _frac_align_np(b, k2, F)
    i1 = (f1 >> (F - 4)) & 0xF
    i2 = (f2 >> (F - 4)) & 0xF
    c = lut[i1 * 16 + i2]

    s = f1 - f2 + c
    kdiff = k1 - k2
    one = np.int64(1) << F
    borrow = s < 0
    # branch: s >= 0 -> 2^kdiff * (1 + s/2^F) ; else 2^(kdiff-1) * (2 + s/2^F)
    mant = np.where(borrow, s + 2 * one, s + one)
    mant = np.maximum(mant, 0)
    shift = kdiff - borrow.astype(np.int64) - F
    if not quantize:
        val = mant.astype(np.float64) * np.exp2(shift.astype(np.float64))
        val = np.where(a == 0, 0.0, val)
        return np.where(b == 0, np.inf, val)
    pos = np.maximum(shift, 0).astype(np.uint64)
    neg = np.minimum(np.maximum(-shift, 0), 63).astype(np.uint64)
    res = (mant.astype(np.uint64) << pos) >> neg
    res = np.where(a == 0, np.uint64(0), res)
    sat = np.uint64((1 << (2 * n_bits)) - 1)
    return np.where(b == 0, sat, res)


# --------------------------------------------------------------------------
# jnp implementation (8/16-bit operands, int32/uint32 intermediates)
# --------------------------------------------------------------------------

def _frac_align(v: jnp.ndarray, k: jnp.ndarray, frac_bits: int) -> jnp.ndarray:
    frac = v - (jnp.int32(1) << k)
    return frac << (frac_bits - k)


def mitchell_mul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    scheme: ErrorScheme = MITCHELL_MUL,
    n_bits: int = 16,
) -> jnp.ndarray:
    """jnp Mitchell/RAPID multiply for unsigned operands < 2**n_bits (<=16).

    Returns uint32 (saturated at 2**32-1, which is unreachable for exact
    16-bit products and only marginally reachable for approximations of
    near-maximal operands).
    """
    assert scheme.kind == "mul" and n_bits <= 16
    F = n_bits - 1
    lut = jnp.asarray(scheme.lut(F), dtype=jnp.int32)
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)

    k1 = ilog2(jnp.maximum(a, 1))
    k2 = ilog2(jnp.maximum(b, 1))
    f1 = _frac_align(a, k1, F)
    f2 = _frac_align(b, k2, F)
    i1 = (f1 >> (F - 4)) & 0xF
    i2 = (f2 >> (F - 4)) & 0xF
    c = jnp.take(lut, i1 * 16 + i2)

    s = f1 + f2 + c
    ksum = k1 + k2
    one = jnp.int32(1) << F
    carry = (s >= one).astype(jnp.int32)
    mant = jnp.maximum(jnp.where(carry == 1, s, s + one), 0).astype(jnp.uint32)
    shift = ksum + carry - F  # in [-(F), n_bits]
    pos = jnp.maximum(shift, 0).astype(jnp.uint32)
    neg = jnp.maximum(-shift, 0).astype(jnp.uint32)
    res = (mant << pos) >> neg
    # saturate: if mant would overflow uint32 on the left shift
    hi_bits = ilog2(jnp.maximum(mant.astype(jnp.int32), 1)) + shift
    res = jnp.where(hi_bits >= 32, jnp.uint32(0xFFFFFFFF), res)
    return jnp.where((a == 0) | (b == 0), jnp.uint32(0), res)


def mitchell_div(
    a: jnp.ndarray,
    b: jnp.ndarray,
    scheme: ErrorScheme = MITCHELL_DIV,
    n_bits: int = 8,
) -> jnp.ndarray:
    """jnp Mitchell/RAPID divide: a < 2**(2*n_bits), b < 2**n_bits (n_bits<=15)."""
    assert scheme.kind == "div" and 2 * n_bits <= 31
    F = 2 * n_bits - 1
    lut = jnp.asarray(scheme.lut(F), dtype=jnp.int32)
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)

    k1 = ilog2(jnp.maximum(a, 1))
    k2 = ilog2(jnp.maximum(b, 1))
    f1 = _frac_align(a, k1, F)
    f2 = _frac_align(b, k2, F)
    i1 = (f1 >> (F - 4)) & 0xF
    i2 = (f2 >> (F - 4)) & 0xF
    c = jnp.take(lut, i1 * 16 + i2)

    s = f1 - f2 + c
    kdiff = k1 - k2
    one = jnp.int32(1) << F
    borrow = (s < 0).astype(jnp.int32)
    mant = jnp.maximum(jnp.where(borrow == 1, s + 2 * one, s + one), 0)
    shift = kdiff - borrow - F
    pos = jnp.maximum(shift, 0).astype(jnp.uint32)
    neg = jnp.minimum(jnp.maximum(-shift, 0), 31).astype(jnp.uint32)
    res = (mant.astype(jnp.uint32) << pos) >> neg
    res = jnp.where(a == 0, jnp.uint32(0), res)
    sat = jnp.uint32((1 << (2 * n_bits)) - 1)
    return jnp.where(b == 0, sat, res)
