"""Truncation-based approximate baselines (DRUM / AAXD style).

The paper's circuit-level and application-level comparisons include the
dynamically-truncated DRUM multiplier [47] and AAXD divider [37]: select
k bits starting at the leading one, set the dropped LSB region to its
midpoint (DRUM's unbiasing trick), operate exactly on the k-bit values,
shift back.  We implement the float-mantissa analogue (truncate the
mantissa to k-1 fraction bits, force the next bit to 1): it has the same
relative-error profile as the integer unit, which is what the QoR
comparison needs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["drum_mul_f32", "aaxd_div_f32"]

_ABS = 0x7FFFFFFF
_SIGN = -0x80000000
_FRAC = 23


def _truncate_mantissa(bits: jnp.ndarray, k: int) -> jnp.ndarray:
    """Keep k-1 mantissa MSBs, set the k-th to 1 (midpoint unbiasing)."""
    drop = _FRAC - (k - 1)
    mask = jnp.int32(-1) << drop
    mid = jnp.int32(1) << (drop - 1)
    return (bits & mask) | mid


def drum_mul_f32(a: jnp.ndarray, b: jnp.ndarray, k: int = 6) -> jnp.ndarray:
    """DRUM-k style approximate product on f32."""
    ba = jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.int32)
    bb = jax.lax.bitcast_convert_type(b.astype(jnp.float32), jnp.int32)
    sign = (ba ^ bb) & _SIGN
    ta = _truncate_mantissa(ba & _ABS, k)
    tb = _truncate_mantissa(bb & _ABS, k)
    fa = jax.lax.bitcast_convert_type(ta, jnp.float32)
    fb = jax.lax.bitcast_convert_type(tb, jnp.float32)
    prod = fa * fb
    pb = jax.lax.bitcast_convert_type(prod, jnp.int32) & _ABS
    out = jax.lax.bitcast_convert_type(pb | sign, jnp.float32)
    return jnp.where((a == 0) | (b == 0), 0.0, out)


def aaxd_div_f32(a: jnp.ndarray, b: jnp.ndarray, k: int = 8) -> jnp.ndarray:
    """AAXD-style approximate quotient on f32 (truncate both operands)."""
    ba = jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.int32)
    bb = jax.lax.bitcast_convert_type(b.astype(jnp.float32), jnp.int32)
    sign = (ba ^ bb) & _SIGN
    ta = _truncate_mantissa(ba & _ABS, k)
    tb = _truncate_mantissa(bb & _ABS, max(2, k // 2))
    fa = jax.lax.bitcast_convert_type(ta, jnp.float32)
    fb = jax.lax.bitcast_convert_type(tb, jnp.float32)
    quo = fa / fb
    qb = jax.lax.bitcast_convert_type(quo, jnp.int32) & _ABS
    out = jax.lax.bitcast_convert_type(qb | sign, jnp.float32)
    out = jnp.where(a == 0, 0.0, out)
    return jnp.where(b == 0, jnp.inf * jnp.sign(a), out)
