"""Unified approximate-arithmetic backend registry.

The paper's core claim is one pipelined log-domain unit reused across
multi-kernel applications; this module is the software analogue: every
``qmatmul`` / ``approx_div`` call site routes through *one* dispatch
layer instead of hand-picking between the jnp scan formulation, the
Pallas TPU kernel, and the elementwise float ops.

A backend bundles two entry points:

  * ``matmul(x2, w2, scheme, *, chunk, bias, activation)`` — 2-D
    ``[M, K] @ [K, N]`` approximate contraction in f32, with an optional
    fused ``activation(out + bias)`` epilogue;
  * ``div(a, b, scheme)`` — elementwise approximate divide.

Built-in backends:

  * ``jnp``              — chunked pure-jnp scan (partitioner-visible;
                           the oracle the kernels are tested against);
  * ``pallas``           — the TPU kernel in ``repro.kernels.log_matmul``
                           (VMEM tiled, grid-pipelined);
  * ``pallas-interpret`` — same kernel under the Pallas interpreter
                           (CPU debugging / CI parity checks).

Elementwise divides are VPU-native already (int sub + 256-gather), so
every built-in backend shares the ``float_approx`` implementation for
``div``; a future fused-softmax kernel can override it per backend.

Selection (``resolve_backend_name``) is one function with a strict
precedence: explicit argument > ``RAPID_BACKEND`` env var > process
default (``set_default_backend``) > hardware autodetect (``pallas`` on
TPU, ``jnp`` elsewhere).  ``None``/"auto" at a call site means "defer to
the next level down".
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa

__all__ = [
    "Backend",
    "ENV_VAR",
    "ACTIVATIONS",
    "normalize_activation",
    "apply_epilogue",
    "register_backend",
    "get_backend",
    "available_backends",
    "resolve_backend_name",
    "set_default_backend",
    "matmul",
    "div",
]

ENV_VAR = "RAPID_BACKEND"

# Fused-epilogue activations.  Keep this table tiny and shared: the Pallas
# kernel applies the *same* jnp function inside the kernel body.  "gelu"
# is jax's default tanh approximation (matches the model zoo's historic
# numerics); "gelu_erf" is the exact erf form, which is additionally
# *bit-stable* across compilation contexts — the tanh approximation's
# mul/add chain gets FMA-fused differently inside vs outside a
# pallas_call, so cross-backend bit-parity checks must use gelu_erf.
ACTIVATIONS: Dict[str, Callable] = {
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "gelu_erf": functools.partial(jax.nn.gelu, approximate=False),
    "tanh": jnp.tanh,
}


def normalize_activation(activation: Optional[str]) -> Optional[str]:
    """Canonicalize an epilogue activation name (None for identity).

    The single validation point for every entry into the fused epilogue
    (ops.qmatmul, backend.apply_epilogue, the Pallas wrapper) so typos
    raise the same clear error everywhere.
    """
    if activation in (None, "none", "linear"):
        return None
    if activation not in ACTIVATIONS:
        raise KeyError(
            f"unknown activation {activation!r}; have {tuple(ACTIVATIONS)}")
    return activation


def apply_epilogue(out: jnp.ndarray, bias, activation: Optional[str]):
    """``activation(out + bias)`` — the shared fused-epilogue semantics.

    ``bias`` is ``None`` or a 1-D ``[N]`` vector broadcast over rows;
    ``activation`` is ``None``/"none" or a key of :data:`ACTIVATIONS`.
    """
    activation = normalize_activation(activation)
    if bias is not None:
        out = out + bias[None, :]
    if activation is not None:
        out = ACTIVATIONS[activation](out)
    return out


# --------------------------------------------------------------------------
# jnp scan formulation (moved here from core/ops.py so the registry owns
# every execution path; ops.py re-exports it for the kernels' oracles).
# --------------------------------------------------------------------------

def log_matmul_scan(
    x: jnp.ndarray, w: jnp.ndarray, lut: jnp.ndarray, chunk: int
) -> jnp.ndarray:
    """RAPID matmul x[M,K] @ w[K,N] via K-chunked log-domain products.

    ``chunk=1`` degenerates to a strictly sequential left-to-right
    accumulation — the same association order as the Pallas kernel's
    rank-1 slab loop, which the bit-exactness tests rely on.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    chunk = min(chunk, k)
    pad = (-k) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    steps = (k + pad) // chunk
    xs = x.reshape(m, steps, chunk).transpose(1, 0, 2)  # [steps, M, C]
    ws = w.reshape(steps, chunk, n)  # [steps, C, N]

    def body(acc, operands):
        xc, wc = operands
        prod = fa.log_mul_f32(xc[:, :, None], wc[None, :, :], lut)  # [M,C,N]
        return acc + prod.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xs, ws))
    return acc


def _matmul_jnp(x2, w2, scheme, *, chunk=64, bias=None, activation=None):
    lut = fa.mul_lut_device(scheme)
    out = log_matmul_scan(x2, w2, lut, chunk)
    return apply_epilogue(out, bias, activation)


def _matmul_pallas(x2, w2, scheme, *, chunk=64, bias=None, activation=None,
                   interpret: Optional[bool] = None):
    # chunk is a jnp-path tuning knob; the kernel has its own block sizes.
    del chunk
    from repro.kernels.log_matmul.ops import log_matmul

    return log_matmul(x2, w2, scheme, bias=bias, activation=activation,
                      interpret=interpret)


def _matmul_pallas_interpret(x2, w2, scheme, **kw):
    kw["interpret"] = True
    return _matmul_pallas(x2, w2, scheme, **kw)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Backend:
    """One named execution path for the approximate ops."""

    name: str
    matmul: Callable
    div: Callable = field(default=fa.approx_div)
    description: str = ""


_REGISTRY: Dict[str, Backend] = {}
_DEFAULT: Optional[str] = None


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Add a backend to the registry (third parties included)."""
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def set_default_backend(name: Optional[str]) -> None:
    """Set (or clear, with ``None``) the process-wide default backend."""
    global _DEFAULT
    if name is not None and name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {available_backends()}")
    _DEFAULT = name


def _autodetect() -> str:
    """Hardware default: pallas only on a *single-device* TPU process.

    The pallas matmul is a per-device kernel; inside pjit-traced
    multi-device code the partitioner must see the jnp formulation (a
    shard_map-aware pallas backend is a ROADMAP item).  Multi-device
    TPU runs that have wired the kernel under shard_map themselves can
    still opt in explicitly (arg/env/set_default_backend).
    """
    try:
        platform = jax.default_backend()
        n_devices = jax.device_count()
    except Exception:  # pragma: no cover - no devices at all
        platform, n_devices = "cpu", 1
    return "pallas" if platform == "tpu" and n_devices == 1 else "jnp"


def resolve_backend_name(name: Optional[str] = None) -> str:
    """One selection function for every call site.

    Precedence: explicit ``name`` > ``$RAPID_BACKEND`` > process default
    (:func:`set_default_backend`) > autodetect (pallas on TPU, else jnp).
    ``None`` and "auto" defer to the next level.
    """
    for candidate in (name, os.environ.get(ENV_VAR), _DEFAULT):
        if candidate and candidate != "auto":
            if candidate not in _REGISTRY:
                raise KeyError(
                    f"unknown backend {candidate!r}; have {available_backends()}")
            return candidate
    return _autodetect()


def get_backend(name: Optional[str] = None) -> Backend:
    """Resolve ``name`` (or the ambient default) to a Backend."""
    return _REGISTRY[resolve_backend_name(name)]


def matmul(x2, w2, scheme, *, backend: Optional[str] = None, **kw):
    """Registry-routed 2-D approximate matmul (see Backend.matmul)."""
    return get_backend(backend).matmul(x2, w2, scheme, **kw)


def div(a, b, scheme, *, backend: Optional[str] = None):
    """Registry-routed elementwise approximate divide."""
    return get_backend(backend).div(a, b, scheme)


register_backend(Backend(
    "jnp", _matmul_jnp,
    description="chunked jnp scan; GSPMD-partitionable oracle"))
register_backend(Backend(
    "pallas", _matmul_pallas,
    description="Pallas TPU kernel (VMEM tiled, grid-pipelined)"))
register_backend(Backend(
    "pallas-interpret", _matmul_pallas_interpret,
    description="Pallas kernel under the interpreter (CPU debug/CI)"))
