"""Unified approximate-arithmetic backend registry.

The paper's core claim is one pipelined log-domain unit reused across
multi-kernel applications; this module is the software analogue: every
``qmatmul`` / ``approx_div`` call site routes through *one* dispatch
layer instead of hand-picking between the jnp scan formulation, the
Pallas TPU kernel, and the elementwise float ops.

A backend bundles four entry points:

  * ``matmul(x2, w2, scheme, *, chunk, bias, activation, residual,
    epilogue)`` — 2-D ``[M, K] @ [K, N]`` approximate contraction in
    f32, with an optional fused output-tile epilogue drawn from the
    **epilogue menu** (see :class:`Epilogue`): any composition of
    ``{bias, activation, residual-add, rms-normalize, softmax-combine}``
    so a whole transformer block tail
    ``norm(activation(x @ w + b) + residual)`` executes in one pass;
  * ``div(a, b, scheme)`` — elementwise approximate divide;
  * ``softmax_div(e, scheme, *, floor)`` — softmax combine:
    ``e / max(sum(e, -1), floor)``, denominator reduction + RAPID divide
    fused in one pass;
  * ``rms_div(x, eps, scheme)`` — rms normalize:
    ``x / sqrt(mean(x^2, -1) + eps)``, likewise fused.

Built-in backends:

  * ``jnp``              — chunked pure-jnp scan (partitioner-visible;
                           the oracle the kernels are tested against);
  * ``pallas``           — the TPU kernels in ``repro.kernels`` (VMEM
                           tiled; ``log_matmul`` for matmuls,
                           ``fused_div`` for the divider family);
  * ``pallas-interpret`` — same kernels under the Pallas interpreter
                           (CPU debugging / CI parity checks).

The divider family — and the epilogue menu's normalization stages —
share canonical semantics with the fused kernels
(``repro.kernels.fused_div.ref``): the denominator reduction runs over
the 128-lane-padded row on every backend, so ``jnp`` and
``pallas-interpret`` agree bit-for-bit.

Selection (``resolve_backend_name``) is one function with a strict
precedence: explicit argument > ``RAPID_BACKEND`` env var > process
default (``set_default_backend``) > hardware autodetect (``pallas`` on
TPU, ``jnp`` elsewhere).  ``None``/"auto" at a call site means "defer to
the next level down".

Manual-mesh awareness: the Pallas kernels are per-device, so on a
multi-device process the hardware level answers differently depending on
*where* the call is traced — ``jnp`` from the global (pjit/GSPMD) view,
but ``pallas`` inside a ``shard_map`` body, where shapes are already
per-shard and the kernel is legal (``repro.compat.in_shard_map``).
Because that answer depends on trace context, :func:`pin_backends`
collapses the arg/env/process-default levels eagerly but pins the
hardware level as the :data:`AUTO_HW` sentinel exactly when it is
context-dependent (multi-device TPU); ``AUTO_HW`` re-consults only the
memoized hardware probe + the axis-env at dispatch time, never the env
var, so a pinned config still cannot be flipped by post-build env
changes.

Per-site overrides: model code never picks a literal backend — it asks
``ApproxConfig.backend_for(site)`` (sites: ``mlp`` / ``attn_proj`` /
``logits`` / ``norm`` / ``softmax``), each of which resolves through the
same selection function.  One model can therefore mix, e.g., pallas
fused-tail MLP matmuls with partitioner-visible jnp logits;
:func:`pin_backends` collapses every site to a concrete registry name
once at engine/trainstep build time.
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass, field, replace as dataclass_replace
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import float_approx as fa
from repro.kernels.fused_div import ref as fdref
from repro.kernels.spec import as_kernel_spec, resolve_spec

__all__ = [
    "Backend",
    "ENV_VAR",
    "AUTO_HW",
    "ACTIVATIONS",
    "SOFTMAX_FLOOR",
    "Epilogue",
    "normalize_activation",
    "as_epilogue",
    "apply_epilogue",
    "apply_epilogue_tile",
    "register_backend",
    "get_backend",
    "available_backends",
    "registered_sites",
    "dispatch_signature",
    "resolve_backend_name",
    "resolve_site_device_local",
    "pin_backend_name",
    "set_default_backend",
    "invalidate_device_probe",
    "pin_backends",
    "matmul",
    "div",
    "softmax_div",
    "rms_div",
    "decode_attn",
]

ENV_VAR = "RAPID_BACKEND"

#: Pinned-but-context-dependent hardware selection: "resolve from the
#: memoized device probe + the trace context (in/out of shard_map) at
#: dispatch time".  pin_backends emits this exactly when the hardware
#: answer differs between the global and the device-local view
#: (multi-device TPU); unlike "auto" it never re-reads the env var or
#: the process default.
AUTO_HW = "auto-hw"

# Default softmax-combine denominator floor (re-exported from the fused
# kernels' canonical-semantics module).
SOFTMAX_FLOOR = fdref.SOFTMAX_FLOOR

# Fused-epilogue activations.  Keep this table tiny and shared: the Pallas
# kernel applies the *same* jnp function inside the kernel body.  "gelu"
# is jax's default tanh approximation (matches the model zoo's historic
# numerics); "gelu_erf" is the exact erf form, which is additionally
# *bit-stable* across compilation contexts — the tanh approximation's
# mul/add chain gets FMA-fused differently inside vs outside a
# pallas_call, so cross-backend bit-parity checks must use gelu_erf.
ACTIVATIONS: Dict[str, Callable] = {
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "gelu_erf": functools.partial(jax.nn.gelu, approximate=False),
    "tanh": jnp.tanh,
}


def normalize_activation(activation: Optional[str]) -> Optional[str]:
    """Canonicalize an epilogue activation name (None for identity).

    The single validation point for every entry into the fused epilogue
    (ops.qmatmul, backend.apply_epilogue, the Pallas wrapper) so typos
    raise the same clear error everywhere.
    """
    if activation in (None, "none", "linear"):
        return None
    if activation not in ACTIVATIONS:
        raise KeyError(
            f"unknown activation {activation!r}; have {tuple(ACTIVATIONS)}")
    return activation


def apply_epilogue(out: jnp.ndarray, bias, activation: Optional[str]):
    """``activation(out + bias)`` — the shared fused-epilogue semantics.

    ``bias`` is ``None`` or a 1-D ``[N]`` vector broadcast over rows;
    ``activation`` is ``None``/"none" or a key of :data:`ACTIVATIONS`.
    """
    activation = normalize_activation(activation)
    if bias is not None:
        out = out + bias[None, :]
    if activation is not None:
        out = ACTIVATIONS[activation](out)
    return out


# --------------------------------------------------------------------------
# Epilogue menu: composable output-tile epilogues
# --------------------------------------------------------------------------

#: Normalization stages the epilogue menu offers.  Both reuse the fused
#: divider kernels' canonical lane-padded denominator semantics
#: (``repro.kernels.fused_div.ref``).
EPILOGUE_NORMS = ("rms", "softmax")


@dataclass(frozen=True)
class Epilogue:
    """What to apply to the output tile on its last K visit.

    The full menu is ``norm(activation(out + bias) + residual)``; every
    stage is optional.  Presence of the *bias* and *residual* stages is
    decided by whether the corresponding operand is passed to the matmul
    — this spec carries the purely-static part (hashable, so it can ride
    jit static args and ``custom_vjp`` nondiff positions):

      * ``activation``   — key of :data:`ACTIVATIONS` (None = identity);
      * ``norm``         — None, "rms" (``z / sqrt(mean(z^2, -1) + eps)``)
                           or "softmax" (``z / max(sum(z, -1), floor)``);
      * ``div_scheme``   — RAPID divider scheme for the norm stage's
                           divide (None = exact IEEE divide);
      * ``eps`` / ``floor`` — the rms / softmax denominator constants;
      * ``keep_prenorm`` — also return the value *before* the norm stage
                           (the residual stream a pre-norm transformer
                           block must carry forward), as ``(tail, pre)``.

    The norm stages reduce over the output's last dim, so they require a
    2-D weight (``qmatmul`` enforces this) and — on the Pallas backend —
    an output tile spanning the full lane-padded row.
    """

    activation: Optional[str] = None
    norm: Optional[str] = None
    div_scheme: Optional[str] = None
    eps: float = 1e-6
    floor: float = SOFTMAX_FLOOR
    keep_prenorm: bool = False

    @property
    def wants_norm_lut(self) -> bool:
        """Whether the norm stage needs an on-device divider LUT."""
        return self.norm is not None and self.div_scheme is not None

    @property
    def is_identity(self) -> bool:
        return self.activation is None and self.norm is None


def as_epilogue(epilogue: Optional[Epilogue],
                activation: Optional[str] = None) -> Epilogue:
    """Canonicalize/validate the (epilogue, activation) call-site pair.

    ``activation=`` is the historical sugar for the activation-only
    epilogue; passing both it and a full spec is ambiguous and raises.
    """
    if epilogue is None:
        return Epilogue(activation=normalize_activation(activation))
    if not isinstance(epilogue, Epilogue):
        raise TypeError(f"epilogue must be an Epilogue, got {epilogue!r}")
    if normalize_activation(activation) is not None:
        raise ValueError("pass the activation inside the Epilogue spec, "
                         "not alongside it")
    if epilogue.norm is not None and epilogue.norm not in EPILOGUE_NORMS:
        raise KeyError(f"unknown epilogue norm {epilogue.norm!r}; "
                       f"have {EPILOGUE_NORMS}")
    if epilogue.keep_prenorm and epilogue.norm is None:
        raise ValueError("keep_prenorm without a norm stage is meaningless")
    act = normalize_activation(epilogue.activation)
    if act != epilogue.activation:
        epilogue = dataclass_replace(epilogue, activation=act)
    return epilogue


def apply_epilogue_tile(z, bias, residual, ep: Epilogue, *, n: int,
                        div_lut=None):
    """Canonical epilogue-menu semantics on one lane-padded row slab.

    ``z`` is ``[rows, n_pad]`` f32 with the real width ``n`` zero-padded
    to a multiple of ``fused_div.ref.LANE``; ``bias`` (``[n_pad]``) and
    ``residual`` (``[rows, n_pad]``) are zero-padded the same way.  Used
    *verbatim* by the jnp oracle and the Pallas kernel epilogue, so the
    two backends agree bit-for-bit by construction.

    Pad-lane invariant: every elementwise stage maps exact zeros to
    exact zeros (zero bias/residual pads; every :data:`ACTIVATIONS`
    entry satisfies ``f(0) == 0``), so the canonical lane-padded
    denominator reductions (``ref.softmax_denom`` / ``ref.rms_denom``)
    only ever see inert zeros in the pad lanes.  A future activation
    with ``f(0) != 0`` would need a pad mask here.

    Compilation-context note: compositions where a mul-tailed activation
    (silu/gelu — their last op is a multiply) feeds the residual add are
    rewritten by XLA when the whole chain sits in one compiled module
    (the divide inside the sigmoid is reformulated against the trailing
    add; optimization barriers do not block it).  Bit-parity for those
    compositions therefore holds between two *compiled* executions —
    which is how models always run — not between eager jnp and a jitted
    kernel; the parity sweep jits the oracle side accordingly.
    """
    if bias is not None:
        z = z + bias[None, :]
    if ep.activation is not None:
        z = ACTIVATIONS[ep.activation](z)
    if residual is not None:
        z = z + residual
    pre = z
    if ep.norm == "softmax":
        denom = fdref.softmax_denom(z, ep.floor)
        z = (fa.log_div_f32(z, denom, div_lut)
             if ep.div_scheme is not None else z / denom)
    elif ep.norm == "rms":
        denom = fdref.rms_denom(z, n, ep.eps)
        z = (fa.log_div_f32(z, denom, div_lut)
             if ep.div_scheme is not None else z / denom)
    return (z, pre) if ep.keep_prenorm else z


# --------------------------------------------------------------------------
# jnp scan formulation (moved here from core/ops.py so the registry owns
# every execution path; ops.py re-exports it for the kernels' oracles).
# --------------------------------------------------------------------------

def log_matmul_scan(
    x: jnp.ndarray, w: jnp.ndarray, lut: jnp.ndarray, chunk: int
) -> jnp.ndarray:
    """RAPID matmul x[M,K] @ w[K,N] via K-chunked log-domain products.

    ``chunk=1`` degenerates to a strictly sequential left-to-right
    accumulation — the same association order as the Pallas kernel's
    rank-1 slab loop, which the bit-exactness tests rely on.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    chunk = min(chunk, k)
    pad = (-k) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    steps = (k + pad) // chunk
    xs = x.reshape(m, steps, chunk).transpose(1, 0, 2)  # [steps, M, C]
    ws = w.reshape(steps, chunk, n)  # [steps, C, N]

    def body(acc, operands):
        xc, wc = operands
        prod = fa.log_mul_f32(xc[:, :, None], wc[None, :, :], lut)  # [M,C,N]
        return acc + prod.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xs, ws))
    return acc


def _finish_epilogue_jnp(out, bias, residual, ep: Epilogue):
    """Apply the epilogue menu to an unpadded [M, N] jnp matmul output.

    Elementwise-only epilogues run unpadded (bit-equal to the padded
    form lane by lane); norm epilogues lane-pad first so the canonical
    tile semantics — shared verbatim with the kernel — see the same
    reduction operand width, then slice the pads back off.
    """
    if ep.norm is None:
        return apply_epilogue_tile(out, bias, residual, ep, n=out.shape[-1])
    n = out.shape[-1]
    div_lut = (fa.div_lut_device(ep.div_scheme)
               if ep.div_scheme is not None else None)
    res = apply_epilogue_tile(
        fdref.pad_lanes(out),
        None if bias is None else fdref.pad_lanes(bias),
        None if residual is None else fdref.pad_lanes(residual),
        ep, n=n, div_lut=div_lut)
    if ep.keep_prenorm:
        return res[0][:, :n], res[1][:, :n]
    return res[:, :n]


def _matmul_jnp(x2, w2, scheme, *, chunk=64, bias=None, activation=None,
                residual=None, epilogue: Optional[Epilogue] = None,
                spec=None):
    # spec is the kernel families' KernelSpec; the scan formulation has
    # no block/pipeline geometry to configure, so it is accepted (the
    # dispatchers pass one spec to every backend uniformly) and ignored.
    del spec
    ep = as_epilogue(epilogue, activation)
    lut = fa.mul_lut_device(scheme)
    out = log_matmul_scan(x2, w2, lut, chunk)
    return _finish_epilogue_jnp(out, bias, residual, ep)


def _matmul_pallas(x2, w2, scheme, *, chunk=64, bias=None, activation=None,
                   residual=None, epilogue: Optional[Epilogue] = None,
                   spec=None, interpret: Optional[bool] = None):
    # chunk is a jnp-path tuning knob; the kernel has its own block
    # sizes, pinned here at the dispatch layer through the resolve_spec
    # choke point (explicit spec field > tuning cache > heuristic); the
    # wrapper's own resolve is then an idempotent no-op.
    del chunk
    from repro.kernels.log_matmul.ops import log_matmul

    ks = as_kernel_spec(spec)
    ep = as_epilogue(epilogue if epilogue is not None else ks.epilogue,
                     activation)
    ks = resolve_spec("log_matmul", (x2.shape[0], w2.shape[1], x2.shape[1]),
                      ks, scheme=scheme or ks.scheme or "rapid10",
                      epilogue=ep)
    return log_matmul(x2, w2, scheme, bias=bias, activation=activation,
                      residual=residual, epilogue=epilogue, spec=ks,
                      interpret=interpret)


def _matmul_pallas_interpret(x2, w2, scheme, **kw):
    kw["interpret"] = True
    return _matmul_pallas(x2, w2, scheme, **kw)


# --------------------------------------------------------------------------
# divider family: elementwise div, fused softmax combine, fused rms
# normalize.  The jnp implementations ARE the canonical semantics (the
# fused kernels evaluate the same expressions on their VMEM tiles).
# --------------------------------------------------------------------------

def _softmax_div_jnp(e, scheme, *, floor=SOFTMAX_FLOOR, spec=None):
    """e / max(sum(e, -1), floor) with the RAPID divider.  f32 in/out."""
    del spec
    return fdref.softmax_div_ref(e, fa.div_lut_device(scheme), floor)


def _rms_div_jnp(x, eps, scheme, *, spec=None):
    """x / sqrt(mean(x^2, -1) + eps) with the RAPID divider.  f32."""
    del spec
    return fdref.rms_div_ref(x, fa.div_lut_device(scheme), eps)


def _div_jnp(a, b, scheme, *, spec=None):
    """Elementwise RAPID divide (the LUT bit-twiddle, no kernel)."""
    del spec
    return fa.approx_div(a, b, scheme)


def _div_pallas(a, b, scheme, *, spec=None,
                interpret: Optional[bool] = None):
    from repro.kernels.fused_div.ops import fused_elementwise_div

    return fused_elementwise_div(a, b, scheme, spec=spec,
                                 interpret=interpret)


def _div_pallas_interpret(a, b, scheme, *, spec=None):
    return _div_pallas(a, b, scheme, spec=spec, interpret=True)


def _row_resolved(family, x, scheme, spec):
    """Pin a fused-divider row spec at the dispatch layer (idempotent
    with the wrapper's own resolve_spec pass)."""
    ks = as_kernel_spec(spec)
    rows = 1
    for d in x.shape[:-1]:
        rows *= int(d)
    return resolve_spec(family, (rows, x.shape[-1]), ks,
                        scheme=scheme or ks.scheme or "rapid9")


def _softmax_div_pallas(e, scheme, *, floor=SOFTMAX_FLOOR, spec=None,
                        interpret: Optional[bool] = None):
    from repro.kernels.fused_div.ops import fused_softmax_div

    return fused_softmax_div(
        e, scheme, floor=floor,
        spec=_row_resolved("fused_softmax", e, scheme, spec),
        interpret=interpret)


def _softmax_div_pallas_interpret(e, scheme, *, floor=SOFTMAX_FLOOR,
                                  spec=None):
    return _softmax_div_pallas(e, scheme, floor=floor, spec=spec,
                               interpret=True)


def _rms_div_pallas(x, eps, scheme, *, spec=None,
                    interpret: Optional[bool] = None):
    from repro.kernels.fused_div.ops import fused_rms_div

    return fused_rms_div(x, eps, scheme,
                         spec=_row_resolved("fused_rms", x, scheme, spec),
                         interpret=interpret)


def _rms_div_pallas_interpret(x, eps, scheme, *, spec=None):
    return _rms_div_pallas(x, eps, scheme, spec=spec, interpret=True)


# --------------------------------------------------------------------------
# decode-attention family: one fused flash-decode step (score matmul,
# online softmax stats, value matmul, floored RAPID combine divide) —
# the flagship consumer of the pipelined kernels.  The jnp impl is the
# canonical semantics (the kernel reproduces it to f32 tolerance; the
# contractions are exact on both paths, only the combine divide is
# approximate).
# --------------------------------------------------------------------------

def _decode_attn_jnp(qf, k_cache, v_cache, slot_positions, pos, window,
                     scheme, *, floor=SOFTMAX_FLOOR, spec=None):
    del spec
    from repro.kernels.flash_attn.ref import decode_attn_ref

    return decode_attn_ref(qf, k_cache, v_cache, slot_positions, pos,
                           window, scheme, floor=floor)


def _decode_attn_pallas(qf, k_cache, v_cache, slot_positions, pos, window,
                        scheme, *, floor=SOFTMAX_FLOOR, spec=None,
                        interpret: Optional[bool] = None):
    from repro.kernels.flash_attn.ops import flash_decode_attn

    b, kv, g, hd = qf.shape
    ks = resolve_spec("flash_attn", (b * kv, k_cache.shape[1], g, hd),
                      as_kernel_spec(spec), scheme=scheme)
    return flash_decode_attn(qf, k_cache, v_cache, slot_positions, pos,
                             window, scheme, floor=floor, spec=ks,
                             interpret=interpret)


def _decode_attn_pallas_interpret(qf, k_cache, v_cache, slot_positions,
                                  pos, window, scheme, *,
                                  floor=SOFTMAX_FLOOR, spec=None):
    return _decode_attn_pallas(qf, k_cache, v_cache, slot_positions, pos,
                               window, scheme, floor=floor, spec=spec,
                               interpret=True)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Backend:
    """One named execution path for the approximate ops."""

    name: str
    matmul: Callable
    div: Callable = field(default=_div_jnp)
    softmax_div: Callable = field(default=_softmax_div_jnp)
    rms_div: Callable = field(default=_rms_div_jnp)
    decode_attn: Callable = field(default=_decode_attn_jnp)
    description: str = ""


_REGISTRY: Dict[str, Backend] = {}
_DEFAULT: Optional[str] = None


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Add a backend to the registry (third parties included)."""
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def registered_sites() -> Tuple[str, ...]:
    """Every site name an ApproxConfig backend map can carry.

    The public answer to "what call sites does the registry dispatch?"
    — the dispatch auditor iterates it, and tests that used to reach
    into ``configs.base.BACKEND_SITES`` (or the private ``_REGISTRY``)
    ask here instead.  "default" leads: it is the fallback every other
    site defers to.
    """
    from repro.configs.base import BACKEND_SITES  # local: avoid cycle

    return ("default",) + BACKEND_SITES


def dispatch_signature(name: str) -> Dict[str, str]:
    """family -> implementing ``module:qualname`` for one backend.

    Introspection for the auditor and tests: states *which function*
    each registry family (matmul / div / softmax_div / rms_div) actually
    dispatches to, without reaching into the private registry dict.
    ``name`` resolves through the normal selection precedence, so
    ``dispatch_signature("auto")`` answers for the ambient default.
    """
    b = _REGISTRY[resolve_backend_name(name)]
    return {
        family: f"{fn.__module__}:{fn.__qualname__}"
        for family, fn in (("matmul", b.matmul), ("div", b.div),
                           ("softmax_div", b.softmax_div),
                           ("rms_div", b.rms_div),
                           ("decode_attn", b.decode_attn))
    }


def set_default_backend(name: Optional[str]) -> None:
    """Set (or clear, with ``None``) the process-wide default backend."""
    global _DEFAULT
    if name is not None and name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {available_backends()}")
    _DEFAULT = name


@functools.lru_cache(maxsize=1)
def _device_probe() -> Tuple[str, int]:
    """Memoized (platform, n_devices) hardware probe.

    ``resolve_backend_name`` runs on *every* dispatch (each qmatmul/qdiv
    trace), while ``jax.device_count()`` walks the live device list each
    call — so the probe is sampled once per process.  Tests that fake
    the device count must call :func:`invalidate_device_probe` after
    (un)patching.
    """
    try:
        return jax.default_backend(), jax.device_count()
    except Exception:  # pragma: no cover - no devices at all
        return "cpu", 1


def invalidate_device_probe() -> None:
    """Drop the memoized (platform, n_devices) sample (test hook)."""
    _device_probe.cache_clear()


def _autodetect(device_local: Optional[bool] = None) -> str:
    """Hardware default: pallas on TPU wherever the call is device-local.

    The pallas matmul is a per-device kernel, so on a multi-device TPU
    process the answer depends on the trace context: pjit-traced global
    code must give the partitioner the jnp formulation, but a
    ``shard_map`` body already sees per-shard shapes and runs the kernel
    on the local shard (the EP/TP paths in ``models/moe.py``).
    ``device_local=None`` consults the axis environment
    (``compat.in_shard_map``); callers that know their locality (e.g. a
    shard_map body resolving before entering the region) pass it
    explicitly.
    """
    platform, n_devices = _device_probe()
    if platform != "tpu":
        return "jnp"
    if n_devices == 1:
        return "pallas"
    if device_local is None:
        device_local = compat.in_shard_map()
    return "pallas" if device_local else "jnp"


def _collapse_levels(name: Optional[str]) -> Optional[str]:
    """The shared arg > env > process-default precedence walk.

    Returns a concrete registry name, :data:`AUTO_HW` when some level
    explicitly requested the hardware step, or ``None`` when every
    level deferred — the two terminals (:func:`resolve_backend_name` /
    :func:`pin_backend_name`) differ only in what they do next.
    """
    for candidate in (name, os.environ.get(ENV_VAR), _DEFAULT):
        if candidate and candidate != "auto":
            if candidate == AUTO_HW:
                return AUTO_HW
            if candidate not in _REGISTRY:
                raise KeyError(
                    f"unknown backend {candidate!r}; have {available_backends()}")
            return candidate
    return None


def resolve_backend_name(name: Optional[str] = None, *,
                         device_local: Optional[bool] = None) -> str:
    """One selection function for every call site.

    Precedence: explicit ``name`` > ``$RAPID_BACKEND`` > process default
    (:func:`set_default_backend`) > autodetect (pallas wherever the call
    is device-local on TPU, else jnp).  ``None`` and "auto" defer to the
    next level; the :data:`AUTO_HW` sentinel (what :func:`pin_backends`
    pins on multi-device TPU) jumps straight to autodetect — the env/
    default levels were already consulted at pin time.  ``device_local``
    overrides the in-shard_map detection at the hardware level.
    """
    got = AUTO_HW if name == AUTO_HW else _collapse_levels(name)
    if got is None or got == AUTO_HW:
        return _autodetect(device_local)
    return got


def pin_backend_name(name: Optional[str] = None) -> str:
    """Build-time companion of :func:`resolve_backend_name`.

    The arg/env/process-default levels collapse to a concrete registry
    name *now* (so later env changes cannot flip a compiled kernel
    choice), but the hardware level stays pinned as :data:`AUTO_HW`
    exactly when its answer depends on trace context — a multi-device
    TPU process, where global-view sites must resolve to jnp while
    shard_map bodies legally run the pallas kernels per shard.  On CPU
    or a single device the hardware answer is context-free and pins
    concretely, exactly as before.
    """
    got = _collapse_levels(name)
    if got is not None and got != AUTO_HW:
        return got
    platform, n_devices = _device_probe()
    if platform == "tpu" and n_devices > 1:
        return AUTO_HW
    return _autodetect(device_local=False)


def resolve_site_device_local(acfg, site: str):
    """Pin one site of an ApproxConfig from the device-local view.

    The helper model code calls right before building a ``shard_map``
    body: the body's dispatches are per-shard, so the site's backend is
    resolved with ``device_local=True`` (an AUTO_HW / auto entry may
    legally become the pallas kernels on a multi-device process) and
    written back as a concrete name, fixing the body's kernel choice
    before tracing begins.  Explicit names pass through unchanged.
    """
    name = resolve_backend_name(acfg.backend_for(site), device_local=True)
    return acfg.with_backends({site: name})


def get_backend(name: Optional[str] = None) -> Backend:
    """Resolve ``name`` (or the ambient default) to a Backend."""
    return _REGISTRY[resolve_backend_name(name)]


def pin_backends(acfg, override: Optional[str] = None):
    """Collapse an ApproxConfig's site->backend map at build time.

    Every site (plus the default) is resolved through
    :func:`pin_backend_name` exactly once, so engines / train steps
    built from the returned config cannot have env-var changes silently
    flip the compiled kernel choice inside a later trace.  ``override``
    (an explicit registry name) wins at every site.  On a multi-device
    TPU, sites left to hardware autodetect pin as :data:`AUTO_HW` — the
    one selection whose answer legitimately differs per call site
    (jnp under pjit, pallas inside shard_map bodies).
    """
    from repro.configs.base import BACKEND_SITES  # local: avoid cycle

    sites = {
        site: pin_backend_name(override or acfg.backend_for(site))
        for site in ("default",) + BACKEND_SITES
    }
    return dataclass_replace(acfg, backends=sites)


def matmul(x2, w2, scheme, *, backend: Optional[str] = None, **kw):
    """Registry-routed 2-D approximate matmul (see Backend.matmul)."""
    return get_backend(backend).matmul(x2, w2, scheme, **kw)


def div(a, b, scheme, *, backend: Optional[str] = None, **kw):
    """Registry-routed elementwise approximate divide."""
    return get_backend(backend).div(a, b, scheme, **kw)


def softmax_div(e, scheme, *, backend: Optional[str] = None,
                floor: float = SOFTMAX_FLOOR, **kw):
    """Registry-routed fused softmax combine (see Backend.softmax_div)."""
    return get_backend(backend).softmax_div(e, scheme, floor=floor, **kw)


def rms_div(x, eps, scheme, *, backend: Optional[str] = None, **kw):
    """Registry-routed fused rms normalize (see Backend.rms_div)."""
    return get_backend(backend).rms_div(x, eps, scheme, **kw)


def decode_attn(qf, k_cache, v_cache, slot_positions, pos, window, scheme,
                *, backend: Optional[str] = None, **kw):
    """Registry-routed fused decode attention (see Backend.decode_attn)."""
    return get_backend(backend).decode_attn(
        qf, k_cache, v_cache, slot_positions, pos, window, scheme, **kw)


register_backend(Backend(
    "jnp", _matmul_jnp,
    description="chunked jnp scan; GSPMD-partitionable oracle"))
register_backend(Backend(
    "pallas", _matmul_pallas,
    div=_div_pallas,
    softmax_div=_softmax_div_pallas,
    rms_div=_rms_div_pallas,
    decode_attn=_decode_attn_pallas,
    description="Pallas TPU kernels (VMEM tiled, software-pipelined)"))
register_backend(Backend(
    "pallas-interpret", _matmul_pallas_interpret,
    div=_div_pallas_interpret,
    softmax_div=_softmax_div_pallas_interpret,
    rms_div=_rms_div_pallas_interpret,
    decode_attn=_decode_attn_pallas_interpret,
    description="Pallas kernels under the interpreter (CPU debug/CI)"))
