"""Unified approximate-arithmetic backend registry.

The paper's core claim is one pipelined log-domain unit reused across
multi-kernel applications; this module is the software analogue: every
``qmatmul`` / ``approx_div`` call site routes through *one* dispatch
layer instead of hand-picking between the jnp scan formulation, the
Pallas TPU kernel, and the elementwise float ops.

A backend bundles four entry points:

  * ``matmul(x2, w2, scheme, *, chunk, bias, activation)`` — 2-D
    ``[M, K] @ [K, N]`` approximate contraction in f32, with an optional
    fused ``activation(out + bias)`` epilogue;
  * ``div(a, b, scheme)`` — elementwise approximate divide;
  * ``softmax_div(e, scheme, *, floor)`` — softmax combine:
    ``e / max(sum(e, -1), floor)``, denominator reduction + RAPID divide
    fused in one pass;
  * ``rms_div(x, eps, scheme)`` — rms normalize:
    ``x / sqrt(mean(x^2, -1) + eps)``, likewise fused.

Built-in backends:

  * ``jnp``              — chunked pure-jnp scan (partitioner-visible;
                           the oracle the kernels are tested against);
  * ``pallas``           — the TPU kernels in ``repro.kernels`` (VMEM
                           tiled; ``log_matmul`` for matmuls,
                           ``fused_div`` for the divider family);
  * ``pallas-interpret`` — same kernels under the Pallas interpreter
                           (CPU debugging / CI parity checks).

The divider family shares canonical semantics with the fused kernels
(``repro.kernels.fused_div.ref``): the denominator reduction runs over
the 128-lane-padded row on every backend, so ``jnp`` and
``pallas-interpret`` agree bit-for-bit.

Selection (``resolve_backend_name``) is one function with a strict
precedence: explicit argument > ``RAPID_BACKEND`` env var > process
default (``set_default_backend``) > hardware autodetect (``pallas`` on
TPU, ``jnp`` elsewhere).  ``None``/"auto" at a call site means "defer to
the next level down".
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import float_approx as fa
from repro.kernels.fused_div import ref as fdref

__all__ = [
    "Backend",
    "ENV_VAR",
    "ACTIVATIONS",
    "SOFTMAX_FLOOR",
    "normalize_activation",
    "apply_epilogue",
    "register_backend",
    "get_backend",
    "available_backends",
    "resolve_backend_name",
    "set_default_backend",
    "matmul",
    "div",
    "softmax_div",
    "rms_div",
]

ENV_VAR = "RAPID_BACKEND"

# Default softmax-combine denominator floor (re-exported from the fused
# kernels' canonical-semantics module).
SOFTMAX_FLOOR = fdref.SOFTMAX_FLOOR

# Fused-epilogue activations.  Keep this table tiny and shared: the Pallas
# kernel applies the *same* jnp function inside the kernel body.  "gelu"
# is jax's default tanh approximation (matches the model zoo's historic
# numerics); "gelu_erf" is the exact erf form, which is additionally
# *bit-stable* across compilation contexts — the tanh approximation's
# mul/add chain gets FMA-fused differently inside vs outside a
# pallas_call, so cross-backend bit-parity checks must use gelu_erf.
ACTIVATIONS: Dict[str, Callable] = {
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "gelu_erf": functools.partial(jax.nn.gelu, approximate=False),
    "tanh": jnp.tanh,
}


def normalize_activation(activation: Optional[str]) -> Optional[str]:
    """Canonicalize an epilogue activation name (None for identity).

    The single validation point for every entry into the fused epilogue
    (ops.qmatmul, backend.apply_epilogue, the Pallas wrapper) so typos
    raise the same clear error everywhere.
    """
    if activation in (None, "none", "linear"):
        return None
    if activation not in ACTIVATIONS:
        raise KeyError(
            f"unknown activation {activation!r}; have {tuple(ACTIVATIONS)}")
    return activation


def apply_epilogue(out: jnp.ndarray, bias, activation: Optional[str]):
    """``activation(out + bias)`` — the shared fused-epilogue semantics.

    ``bias`` is ``None`` or a 1-D ``[N]`` vector broadcast over rows;
    ``activation`` is ``None``/"none" or a key of :data:`ACTIVATIONS`.
    """
    activation = normalize_activation(activation)
    if bias is not None:
        out = out + bias[None, :]
    if activation is not None:
        out = ACTIVATIONS[activation](out)
    return out


# --------------------------------------------------------------------------
# jnp scan formulation (moved here from core/ops.py so the registry owns
# every execution path; ops.py re-exports it for the kernels' oracles).
# --------------------------------------------------------------------------

def log_matmul_scan(
    x: jnp.ndarray, w: jnp.ndarray, lut: jnp.ndarray, chunk: int
) -> jnp.ndarray:
    """RAPID matmul x[M,K] @ w[K,N] via K-chunked log-domain products.

    ``chunk=1`` degenerates to a strictly sequential left-to-right
    accumulation — the same association order as the Pallas kernel's
    rank-1 slab loop, which the bit-exactness tests rely on.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    chunk = min(chunk, k)
    pad = (-k) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    steps = (k + pad) // chunk
    xs = x.reshape(m, steps, chunk).transpose(1, 0, 2)  # [steps, M, C]
    ws = w.reshape(steps, chunk, n)  # [steps, C, N]

    def body(acc, operands):
        xc, wc = operands
        prod = fa.log_mul_f32(xc[:, :, None], wc[None, :, :], lut)  # [M,C,N]
        return acc + prod.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xs, ws))
    return acc


def _matmul_jnp(x2, w2, scheme, *, chunk=64, bias=None, activation=None):
    lut = fa.mul_lut_device(scheme)
    out = log_matmul_scan(x2, w2, lut, chunk)
    return apply_epilogue(out, bias, activation)


def _matmul_pallas(x2, w2, scheme, *, chunk=64, bias=None, activation=None,
                   interpret: Optional[bool] = None):
    # chunk is a jnp-path tuning knob; the kernel has its own block sizes.
    del chunk
    from repro.kernels.log_matmul.ops import log_matmul

    return log_matmul(x2, w2, scheme, bias=bias, activation=activation,
                      interpret=interpret)


def _matmul_pallas_interpret(x2, w2, scheme, **kw):
    kw["interpret"] = True
    return _matmul_pallas(x2, w2, scheme, **kw)


# --------------------------------------------------------------------------
# divider family: elementwise div, fused softmax combine, fused rms
# normalize.  The jnp implementations ARE the canonical semantics (the
# fused kernels evaluate the same expressions on their VMEM tiles).
# --------------------------------------------------------------------------

def _softmax_div_jnp(e, scheme, *, floor=SOFTMAX_FLOOR):
    """e / max(sum(e, -1), floor) with the RAPID divider.  f32 in/out."""
    return fdref.softmax_div_ref(e, fa.div_lut_device(scheme), floor)


def _rms_div_jnp(x, eps, scheme):
    """x / sqrt(mean(x^2, -1) + eps) with the RAPID divider.  f32."""
    return fdref.rms_div_ref(x, fa.div_lut_device(scheme), eps)


def _div_pallas(a, b, scheme, *, interpret: Optional[bool] = None):
    from repro.kernels.fused_div.ops import fused_elementwise_div

    return fused_elementwise_div(a, b, scheme, interpret=interpret)


def _div_pallas_interpret(a, b, scheme):
    return _div_pallas(a, b, scheme, interpret=True)


def _softmax_div_pallas(e, scheme, *, floor=SOFTMAX_FLOOR,
                        interpret: Optional[bool] = None):
    from repro.kernels.fused_div.ops import fused_softmax_div

    return fused_softmax_div(e, scheme, floor=floor, interpret=interpret)


def _softmax_div_pallas_interpret(e, scheme, *, floor=SOFTMAX_FLOOR):
    return _softmax_div_pallas(e, scheme, floor=floor, interpret=True)


def _rms_div_pallas(x, eps, scheme, *, interpret: Optional[bool] = None):
    from repro.kernels.fused_div.ops import fused_rms_div

    return fused_rms_div(x, eps, scheme, interpret=interpret)


def _rms_div_pallas_interpret(x, eps, scheme):
    return _rms_div_pallas(x, eps, scheme, interpret=True)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Backend:
    """One named execution path for the approximate ops."""

    name: str
    matmul: Callable
    div: Callable = field(default=fa.approx_div)
    softmax_div: Callable = field(default=_softmax_div_jnp)
    rms_div: Callable = field(default=_rms_div_jnp)
    description: str = ""


_REGISTRY: Dict[str, Backend] = {}
_DEFAULT: Optional[str] = None


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Add a backend to the registry (third parties included)."""
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def set_default_backend(name: Optional[str]) -> None:
    """Set (or clear, with ``None``) the process-wide default backend."""
    global _DEFAULT
    if name is not None and name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {available_backends()}")
    _DEFAULT = name


def _autodetect() -> str:
    """Hardware default: pallas only on a *single-device* TPU process.

    The pallas matmul is a per-device kernel; inside pjit-traced
    multi-device code the partitioner must see the jnp formulation (a
    shard_map-aware pallas backend is a ROADMAP item).  Multi-device
    TPU runs that have wired the kernel under shard_map themselves can
    still opt in explicitly (arg/env/set_default_backend).
    """
    try:
        platform = jax.default_backend()
        n_devices = jax.device_count()
    except Exception:  # pragma: no cover - no devices at all
        platform, n_devices = "cpu", 1
    return "pallas" if platform == "tpu" and n_devices == 1 else "jnp"


def resolve_backend_name(name: Optional[str] = None) -> str:
    """One selection function for every call site.

    Precedence: explicit ``name`` > ``$RAPID_BACKEND`` > process default
    (:func:`set_default_backend`) > autodetect (pallas on TPU, else jnp).
    ``None`` and "auto" defer to the next level.
    """
    for candidate in (name, os.environ.get(ENV_VAR), _DEFAULT):
        if candidate and candidate != "auto":
            if candidate not in _REGISTRY:
                raise KeyError(
                    f"unknown backend {candidate!r}; have {available_backends()}")
            return candidate
    return _autodetect()


def get_backend(name: Optional[str] = None) -> Backend:
    """Resolve ``name`` (or the ambient default) to a Backend."""
    return _REGISTRY[resolve_backend_name(name)]


def matmul(x2, w2, scheme, *, backend: Optional[str] = None, **kw):
    """Registry-routed 2-D approximate matmul (see Backend.matmul)."""
    return get_backend(backend).matmul(x2, w2, scheme, **kw)


def div(a, b, scheme, *, backend: Optional[str] = None):
    """Registry-routed elementwise approximate divide."""
    return get_backend(backend).div(a, b, scheme)


def softmax_div(e, scheme, *, backend: Optional[str] = None,
                floor: float = SOFTMAX_FLOOR):
    """Registry-routed fused softmax combine (see Backend.softmax_div)."""
    return get_backend(backend).softmax_div(e, scheme, floor=floor)


def rms_div(x, eps, scheme, *, backend: Optional[str] = None):
    """Registry-routed fused rms normalize (see Backend.rms_div)."""
    return get_backend(backend).rms_div(x, eps, scheme)


register_backend(Backend(
    "jnp", _matmul_jnp,
    description="chunked jnp scan; GSPMD-partitionable oracle"))
register_backend(Backend(
    "pallas", _matmul_pallas,
    div=_div_pallas,
    softmax_div=_softmax_div_pallas,
    rms_div=_rms_div_pallas,
    description="Pallas TPU kernels (VMEM tiled, grid-pipelined)"))
register_backend(Backend(
    "pallas-interpret", _matmul_pallas_interpret,
    div=_div_pallas_interpret,
    softmax_div=_softmax_div_pallas_interpret,
    rms_div=_rms_div_pallas_interpret,
    description="Pallas kernels under the interpreter (CPU debug/CI)"))
