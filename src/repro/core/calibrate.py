"""Derivation of RAPID error-reduction schemes.

The paper partitions the (x1, x2) fraction-pair unit square — addressed by
the 4 MSBs of each operand fraction, i.e. a 16x16 cell grid — into a small
number of groups (3/5/10 for the multiplier, 3/5/9 for the divider), each
with one signed coefficient added inside the fraction addition.  The exact
partitions of Fig. 2 are derived from an error-integral analysis (following
REALM [45]); we reproduce that derivation numerically:

  1. model the continuous Mitchell relative error per cell (the paper shows
     the error replicates across every power-of-two interval, so the
     continuous model is bit-width independent);
  2. per-cell L1-optimal coefficients via the weighted-median of the
     pointwise ideal corrections;
  3. Lloyd iterations: cluster cells into G groups by which group
     coefficient minimises the cell's mean |relative error|, then refit
     each group coefficient on its member cells;
  4. the result is a (16,16)->group assignment + G coefficients, exactly
     realisable in hardware as a casex/LUT over the 8 index bits (and on
     TPU as a 256-entry gather).

Run ``python -m repro.core.calibrate`` to regenerate ``schemes.py`` tables
and print the continuous-domain ARE/PRE/bias for each scheme.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "continuous_rel_error",
    "derive_scheme",
    "scheme_metrics",
]

_GRID = 64  # sub-samples per cell axis -> 1024x1024 total points


def _cell_points(grid: int = _GRID) -> Tuple[np.ndarray, np.ndarray]:
    """Midpoint sub-grid of one 1/16-wide cell, offsets in [0, 1/16)."""
    step = 1.0 / (16 * grid)
    offs = (np.arange(grid) + 0.5) * step
    return np.meshgrid(offs, offs, indexing="ij")


def continuous_rel_error(
    x1: np.ndarray, x2: np.ndarray, c: float | np.ndarray, kind: str
) -> np.ndarray:
    """Relative error of Mitchell+coefficient at fraction pair (x1, x2)."""
    if kind == "mul":
        s = x1 + x2 + c
        approx = np.where(s < 1.0, 1.0 + s, 2.0 * s)
        true = (1.0 + x1) * (1.0 + x2)
    else:
        s = x1 - x2 + c
        approx = np.where(s >= 0.0, 1.0 + s, (2.0 + s) / 2.0)
        true = (1.0 + x1) / (1.0 + x2)
    return approx / true - 1.0


def _ideal_c(x1: np.ndarray, x2: np.ndarray, kind: str) -> np.ndarray:
    """Pointwise coefficient giving zero error (branch-aware, continuous)."""
    if kind == "mul":
        true = (1.0 + x1) * (1.0 + x2)  # in [1, 4)
        return np.where(true < 2.0, true - 1.0, true / 2.0) - (x1 + x2)
    true = (1.0 + x1) / (1.0 + x2)  # in (0.5, 2)
    return np.where(true >= 1.0, true - 1.0, 2.0 * true - 2.0) - (x1 - x2)


def _weighted_median(values: np.ndarray, weights: np.ndarray) -> float:
    order = np.argsort(values)
    v, w = values[order], weights[order]
    cw = np.cumsum(w)
    return float(v[np.searchsorted(cw, 0.5 * cw[-1])])


def _polish(
    x1: np.ndarray, x2: np.ndarray, c0: float, kind: str, span: float = 0.02
) -> float:
    """Local grid refinement of c around c0 on the exact L1 objective."""
    best_c, best = c0, np.abs(continuous_rel_error(x1, x2, c0, kind)).mean()
    for c in np.linspace(c0 - span, c0 + span, 81):
        v = np.abs(continuous_rel_error(x1, x2, c, kind)).mean()
        if v < best:
            best, best_c = v, c
    return best_c


def derive_scheme(kind: str, n_groups: int, grid: int = _GRID, iters: int = 40):
    """Return (assign (16,16) int array, coeffs (G,) float array)."""
    dx1, dx2 = _cell_points(grid)
    # Per-cell point clouds: cells[i,j] covers x1 in [i/16,(i+1)/16) etc.
    cell_x1 = np.empty((16, 16) + dx1.shape)
    cell_x2 = np.empty_like(cell_x1)
    for i in range(16):
        for j in range(16):
            cell_x1[i, j] = i / 16.0 + dx1
            cell_x2[i, j] = j / 16.0 + dx2

    # 1) per-cell optimal coefficient
    cell_opt = np.empty((16, 16))
    for i in range(16):
        for j in range(16):
            x1, x2 = cell_x1[i, j].ravel(), cell_x2[i, j].ravel()
            ideal = _ideal_c(x1, x2, kind)
            true = (1 + x1) * (1 + x2) if kind == "mul" else (1 + x1) / (1 + x2)
            c0 = _weighted_median(ideal, 1.0 / true)
            cell_opt[i, j] = _polish(x1, x2, c0, kind)

    # 2) Lloyd iterations over group coefficients
    qs = (np.arange(n_groups) + 0.5) / n_groups
    coeffs = np.quantile(cell_opt.ravel(), qs)
    assign = np.zeros((16, 16), dtype=np.int64)
    for _ in range(iters):
        # assignment step: per cell, group minimising exact cell objective
        new_assign = np.zeros_like(assign)
        for i in range(16):
            for j in range(16):
                x1, x2 = cell_x1[i, j].ravel(), cell_x2[i, j].ravel()
                objs = [
                    np.abs(continuous_rel_error(x1, x2, c, kind)).mean()
                    for c in coeffs
                ]
                new_assign[i, j] = int(np.argmin(objs))
        # update step: refit each group's coefficient on its members
        new_coeffs = coeffs.copy()
        for g in range(n_groups):
            mask = new_assign == g
            if not mask.any():
                continue
            x1 = cell_x1[mask].ravel()
            x2 = cell_x2[mask].ravel()
            ideal = _ideal_c(x1, x2, kind)
            true = (1 + x1) * (1 + x2) if kind == "mul" else (1 + x1) / (1 + x2)
            c0 = _weighted_median(ideal, 1.0 / true)
            new_coeffs[g] = _polish(x1, x2, c0, kind)
        if (new_assign == assign).all() and np.allclose(new_coeffs, coeffs):
            break
        assign, coeffs = new_assign, new_coeffs
    return assign, coeffs


def scheme_metrics(assign, coeffs, kind: str, grid: int = 256):
    """Continuous-domain (ARE%, PRE%, bias%) of a scheme."""
    step = 1.0 / grid
    xs = (np.arange(grid) + 0.5) * step
    x1, x2 = np.meshgrid(xs, xs, indexing="ij")
    i1 = np.minimum((x1 * 16).astype(np.int64), 15)
    i2 = np.minimum((x2 * 16).astype(np.int64), 15)
    c = np.asarray(coeffs)[np.asarray(assign)[i1, i2]]
    re = continuous_rel_error(x1, x2, c, kind)
    return (
        100 * np.abs(re).mean(),
        100 * np.abs(re).max(),
        100 * re.mean(),
    )


def _fmt_assign(assign: np.ndarray) -> str:
    rows = [
        "        (" + ", ".join(str(int(v)) for v in row) + "),"
        for row in assign
    ]
    return "    (\n" + "\n".join(rows) + "\n    )"


def main() -> None:
    specs = [
        ("mul", 3, "RAPID3_MUL"),
        ("mul", 5, "RAPID5_MUL"),
        ("mul", 10, "RAPID10_MUL"),
        ("div", 3, "RAPID3_DIV"),
        ("div", 5, "RAPID5_DIV"),
        ("div", 9, "RAPID9_DIV"),
    ]
    print("# Auto-generated by `python -m repro.core.calibrate` — paste into schemes.py")
    for kind, g, name in specs:
        assign, coeffs = derive_scheme(kind, g)
        are, pre, bias = scheme_metrics(assign, coeffs, kind)
        print(f"\n# {name}: continuous ARE={are:.3f}% PRE={pre:.3f}% bias={bias:+.4f}%")
        print(f"{name} = ErrorScheme(")
        print(f'    "{name.lower()}", "{kind}",')
        print(_fmt_assign(assign) + ",")
        print("    (" + ", ".join(f"{c:.8f}" for c in coeffs) + "),")
        print(")")
    # plain Mitchell reference numbers
    for kind in ("mul", "div"):
        zero = np.zeros((16, 16), dtype=np.int64)
        are, pre, bias = scheme_metrics(zero, np.zeros(1), kind)
        print(f"\n# MITCHELL_{kind.upper()}: ARE={are:.3f}% PRE={pre:.3f}% bias={bias:+.4f}%")


if __name__ == "__main__":
    main()
