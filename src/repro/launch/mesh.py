"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state.  The single-pod
mesh is 16x16 = 256 chips (data x model); the multi-pod mesh adds a
leading 2-pod axis (512 chips) used as an outer data-parallel dimension
(params replicate across pods; batch shards over pod x data).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
