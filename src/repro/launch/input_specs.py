"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

Nothing here allocates device memory: batches, params, optimizer state and
decode caches are all ``jax.ShapeDtypeStruct`` trees derived from the
single-source-of-truth P-spec trees.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import SHAPES, ModelConfig
from repro.models.model import Model, _VIS_DIM
from repro.models.params import pspec_tree, shape_tree

__all__ = ["batch_specs", "cell_struct", "supports_shape", "skip_reason"]


def supports_shape(cfg: ModelConfig, shape_name: str) -> bool:
    return skip_reason(cfg, shape_name) is None


def skip_reason(cfg: ModelConfig, shape_name: str):
    """Assignment rules: long_500k needs a sub-quadratic memory path."""
    if shape_name != "long_500k":
        return None
    sub_quadratic = (
        cfg.family in ("ssm", "hybrid")      # state-space / hybrid
        or cfg.sliding_window > 0            # windowed attention
    )
    if not sub_quadratic:
        return (f"{cfg.name} is a pure full-attention arch: a 512k KV cache "
                "decode step is quadratic-memory; skipped per brief "
                "(see DESIGN.md SSArch-applicability)")
    return None


def batch_specs(cfg: ModelConfig, shape_name: str) -> Tuple[dict, dict]:
    """Returns (ShapeDtypeStruct dict, PartitionSpec dict) for the batch."""
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    i32 = jnp.int32
    cdt = jnp.dtype(cfg.dtype)
    batch_ps = None  # filled by caller rules; here use logical marker
    specs, pspecs = {}, {}

    def add(name, shape, dtype, ps):
        specs[name] = jax.ShapeDtypeStruct(shape, dtype)
        pspecs[name] = ps

    if kind == "train":
        S_tok = S - cfg.frontend_seq if cfg.family == "vlm" else S
        add("tokens", (B, S_tok), i32, ("batch", None))
        add("targets", (B, S_tok), i32, ("batch", None))
        if cfg.family == "encdec":
            add("enc_embeds", (B, cfg.frontend_seq, _VIS_DIM), cdt,
                ("batch", None, None))
        if cfg.family == "vlm":
            add("patches", (B, cfg.frontend_seq, _VIS_DIM), cdt,
                ("batch", None, None))
    elif kind == "prefill":
        S_tok = S - cfg.frontend_seq if cfg.family == "vlm" else S
        add("tokens", (B, S_tok), i32, ("batch", None))
        if cfg.family == "encdec":
            add("enc_embeds", (B, cfg.frontend_seq, _VIS_DIM), cdt,
                ("batch", None, None))
        if cfg.family == "vlm":
            add("patches", (B, cfg.frontend_seq, _VIS_DIM), cdt,
                ("batch", None, None))
    else:  # decode
        add("tokens", (B,), i32, ("batch",))
    return specs, pspecs


def cell_struct(cfg: ModelConfig, shape_name: str, rules: dict, mesh,
                opt_cfg=None):
    """Everything the dry-run needs for one cell.

    Returns dict with: kind, batch (structs), in_shardings trees, params
    struct, and for decode: cache struct; for train: opt struct.
    """
    from repro.train.optimizer import OptConfig, opt_param_specs

    model = Model(cfg)
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    B, S = sh["global_batch"], sh["seq_len"]

    def ns(ps_tree):
        return jax.tree.map(
            lambda ps: NamedSharding(mesh, ps), ps_tree,
            is_leaf=lambda x: isinstance(x, PartitionSpec))

    def resolve(logical):
        return PartitionSpec(*(rules.get(a) if a else None for a in logical))

    pspecs = model.pspecs(rules)
    params = model.param_shapes()
    bstruct, blogical = batch_specs(cfg, shape_name)
    bshard = {k: NamedSharding(mesh, resolve(v)) for k, v in blogical.items()}

    out = dict(kind=kind, model=model, params=params,
               params_shardings=ns(pspecs), batch=bstruct,
               batch_shardings=bshard)

    if kind == "train":
        oc = opt_cfg or OptConfig(name=cfg.optimizer)
        ospec = opt_param_specs(model.param_specs(), oc)
        out["opt"] = shape_tree(ospec)
        out["opt_shardings"] = ns(pspec_tree(ospec, rules))
        out["opt_cfg"] = oc
    elif kind == "decode":
        cspec = model.cache_specs(B, S)
        out["cache"] = shape_tree(cspec)
        out["cache_shardings"] = ns(pspec_tree(cspec, rules))
    elif kind == "prefill":
        # the produced cache is an *output*: pin its sharding so the 32k
        # KV buffers leave the step seq-sharded rather than replicated
        cspec = model.cache_specs(B, S)
        out["cache_shardings"] = ns(pspec_tree(cspec, rules))
    return out
