"""Operator CLI: dispatch-coverage audit with optional HLO cross-check.

``python -m repro.launch.audit`` wraps the three-layer auditor
(``repro.analysis``) for operators who want one command that

  * runs the AST lint + jaxpr census + kernel geometry audit against
    ``AUDIT_baseline.json`` (auto-detected at the repo root when
    ``--baseline`` is omitted), optionally writing the kernel
    pipeline-legality report (``--pipeline-report path``), and
  * optionally cross-checks a dumped HLO module (``--hlo path``): the
    jaxpr census counts dot/div *equations*; ``count_ops`` counts the
    ``dot`` / ``divide`` instructions XLA actually emitted.  A compiled
    count far above the traced one means XLA re-materialised arithmetic
    the registry never saw (e.g. constant-folding got disabled), which
    the trace-level audit alone cannot catch.

Dump HLO for the cross-check with
``jax.jit(fn).lower(*args).compile().as_text()`` or via the dryrun
tooling in :mod:`repro.launch.dryrun`.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

__all__ = ["main", "hlo_crosscheck"]


def hlo_crosscheck(hlo_text: str, jaxpr_meta: dict) -> List[str]:
    """Compare compiled dot/divide counts against the traced census.

    Returns human-readable lines; never fails the run — compiled counts
    legitimately differ (fusion duplication, algebraic rewrites), so the
    cross-check is a report, not a gate.
    """
    from repro.launch.hlo_analysis import count_ops

    compiled = count_ops(hlo_text, ops=("dot", "divide"))
    traced = sum(m.get("eqns_audited", 0) for m in jaxpr_meta.values())
    lines = [
        f"hlo cross-check: compiled dot={compiled['dot']} "
        f"divide={compiled['divide']} vs {traced} traced dot/div eqns "
        f"across {len(jaxpr_meta)} entries",
    ]
    n_compiled = compiled["dot"] + compiled["divide"]
    if traced and n_compiled > 2 * traced:
        lines.append(
            "hlo cross-check: compiled count exceeds 2x the traced census "
            "— XLA may be re-materialising arithmetic outside the registry")
    return lines


def _default_baseline() -> str:
    root = Path(__file__).resolve().parents[3]
    p = root / "AUDIT_baseline.json"
    return str(p) if p.exists() else ""


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.audit",
        description="dispatch-coverage audit (lint + jaxpr) with optional "
                    "HLO cross-check")
    ap.add_argument("--entries", default="",
                    help="comma-separated jaxpr entry subset (default: all)")
    ap.add_argument("--baseline", default=_default_baseline(),
                    metavar="PATH", help="ratchet baseline "
                    "(default: repo AUDIT_baseline.json if present)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the merged JSON report")
    ap.add_argument("--hlo", default="", metavar="PATH",
                    help="dumped HLO text to cross-check against")
    ap.add_argument("--pipeline-report", default="", metavar="PATH",
                    help="write the kernel pipeline-legality report JSON")
    ap.add_argument("--fail-stale", action="store_true",
                    help="exit nonzero on stale baseline entries")
    ap.add_argument("--prune-stale", action="store_true",
                    help="rewrite the baseline without stale entries")
    args = ap.parse_args(argv)

    from repro.analysis.__main__ import run_combined

    rc, _, jaxpr_meta = run_combined(
        entries=[n for n in args.entries.split(",") if n] or None,
        baseline=args.baseline or None, json_path=args.json or None,
        fail_stale=args.fail_stale, prune_stale=args.prune_stale,
        pipeline_report=args.pipeline_report or None)

    if args.hlo:
        hlo_text = Path(args.hlo).read_text()
        for line in hlo_crosscheck(hlo_text, jaxpr_meta):
            sys.stdout.write(line + "\n")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
