"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The FIRST two lines below must run before any other import (jax locks the
device count on first init).  This is the only entry point that fakes 512
host devices — tests and benchmarks see the real single device.

Per cell we AOT-compile the real step function (train_step with optimizer
update / prefill / decode) against ShapeDtypeStruct stand-ins — no memory
is allocated — then record:

  * memory_analysis()  — per-device bytes (proves the cell fits HBM),
  * cost_analysis()    — XLA's own numbers (kept for reference),
  * analyze_hlo()      — trip-weighted flops / HBM bytes / collective
                         bytes parsed from the compiled HLO,
  * the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.

Results are cached as JSON under experiments/dryrun/; rerun with --force
to refresh.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--approx]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, RAPID, SHAPES, get_config
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.input_specs import cell_struct, skip_reason
from repro.launch.mesh import make_production_mesh
from repro.models.layers import ParallelCtx
from repro.parallel.sharding import make_rules
from repro.train.trainstep import make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops(cfg, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for the cell's token count."""
    from repro.models.model import Model
    from repro.models.params import count_params

    total = count_params(Model(cfg).param_specs())
    if cfg.n_experts:
        # active params: replace expert count by experts_per_token
        dense_like = total
        spec = Model(cfg).param_specs()
        import numpy as np

        moe_leaves = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                spec, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))[0]:
            names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if any(n in ("w1", "w2", "w3") for n in names) and "expert" in leaf.axes:
                moe_leaves += int(np.prod(leaf.shape))
        active = total - moe_leaves + moe_leaves * (
            cfg.experts_per_token / cfg.n_experts)
        total = active
    sh = SHAPES[shape_name]
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * total * tokens
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * total * tokens
    return 2.0 * total * sh["global_batch"]  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             approx: bool = False, force: bool = False,
             backend: str = None, site_backends: dict = None) -> dict:
    mesh_tag = "pod2" if multi_pod else "pod1"
    tag = f"{arch}__{shape_name}__{mesh_tag}" + ("__rapid" if approx else "")
    out_path = OUT_DIR / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if approx:
        cfg = cfg.with_(approx=RAPID)
    if backend:
        cfg = cfg.with_backend(backend)
    if site_backends:
        cfg = cfg.with_site_backends(site_backends)
    reason = skip_reason(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "approx": approx, "time": time.strftime("%F %T")}
    if reason:
        rec["skipped"] = reason
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    shard_cache_seq = kind in ("decode", "prefill") and cfg.family != "ssm"
    # pure DP/FSDP pays off only while params+moments fit under data-axis
    # FSDP (~<= 12B params at f32 Adam on 16 GB chips)
    from repro.models.params import count_params
    from repro.models.model import Model as _M

    n_params = count_params(_M(cfg).param_specs())
    pure_dp = (kind == "train" and cfg.n_experts == 0
               and sh["global_batch"] % n_chips == 0
               and n_params <= 12e9)
    rules = make_rules(cfg, multi_pod=multi_pod,
                       shard_cache_seq=shard_cache_seq,
                       shard_batch=sh["global_batch"] > 1,
                       seq_parallel=kind != "decode",
                       pure_dp=pure_dp)
    ctx = ParallelCtx(mesh, rules)
    cell = cell_struct(cfg, shape_name, rules, mesh)
    model = cell["model"]
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    # gradient-accumulation microbatches for the biggest models: the
    # per-microbatch activation footprint is what must fit HBM
    microbatches = {"jamba_1_5_large_398b": 8, "qwen3_moe_235b_a22b": 8,
                    "llava_next_34b": 2, "llama4_scout_17b_a16e": 4}.get(
                        arch, 1) if kind == "train" else 1
    try:
        if kind == "train":
            _, train_step = make_train_step(model, cell["opt_cfg"], ctx,
                                            microbatches=microbatches)
            jitted = jax.jit(
                train_step,
                in_shardings=(cell["params_shardings"], cell["opt_shardings"],
                              cell["batch_shardings"], repl),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(cell["params"], cell["opt"], cell["batch"],
                                   jax.ShapeDtypeStruct((), jnp.int32))
        elif kind == "prefill":
            S = sh["seq_len"]

            def prefill_fn(params, batch):
                return model.prefill(params, batch, ctx, cache_n=S)

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(cell["params_shardings"], cell["batch_shardings"]),
                out_shardings=(None, cell["cache_shardings"]),
            )
            lowered = jitted.lower(cell["params"], cell["batch"])
        else:  # decode
            seq_axis = "model" if shard_cache_seq else None

            def decode_fn(params, tokens, cache):
                return model.decode_step(params, tokens, cache, ctx,
                                         seq_shard_axis=seq_axis)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(cell["params_shardings"],
                              cell["batch_shardings"]["tokens"],
                              cell["cache_shardings"]),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(cell["params"], cell["batch"]["tokens"],
                                   cell["cache"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:  # record the failure for triage
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-4000:]
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
        raise

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    ana = analyze_hlo(hlo)
    terms = roofline_terms(ana["flops"], ana["hbm_bytes"],
                           ana["collectives"]["total"])
    mf = model_flops(cfg, shape_name)
    hlo_flops_total = ana["flops"] * n_chips
    rec.update({
        "microbatches": microbatches,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "n_chips": n_chips,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": (mem.argument_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 + mem.output_size_in_bytes
                                 - mem.alias_size_in_bytes),
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed", "transcendentals")},
        "hlo_analysis": {
            "flops_per_dev": ana["flops"],
            "hbm_bytes_per_dev": ana["hbm_bytes"],
            "collectives_per_dev": ana["collectives"],
        },
        "roofline": terms,
        "model_flops_total": mf,
        "useful_flops_ratio": mf / hlo_flops_total if hlo_flops_total else None,
    })
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--approx", action="store_true",
                    help="RAPID approximate mode (paper technique on)")
    ap.add_argument("--force", action="store_true")
    from repro.launch.backend_args import add_backend_args, parse_site_backends
    add_backend_args(ap)
    args = ap.parse_args()
    site_backends = parse_site_backends(args.site_backend)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    ok = fail = skip = 0
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           approx=args.approx, force=args.force,
                           backend=args.backend, site_backends=site_backends)
            if "skipped" in rec:
                skip += 1
                print(f"[SKIP] {arch} {shape}: {rec['skipped'][:80]}")
            else:
                ok += 1
                r = rec["roofline"]
                print(f"[ OK ] {arch} {shape} ({rec['mesh']}): "
                      f"compile={rec.get('compile_s', '?')}s "
                      f"dominant={r['dominant']} "
                      f"c/m/coll={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
                      f"{r['collective_s']:.2e}s "
                      f"mem={rec['memory']['per_device_total']/2**30:.2f}GiB")
        except Exception as e:
            fail += 1
            print(f"[FAIL] {arch} {shape}: {type(e).__name__}: {str(e)[:200]}")
    print(f"\n{ok} ok, {skip} skipped, {fail} failed")
    return 0 if fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
