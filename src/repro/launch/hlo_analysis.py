"""Post-compile HLO analysis: trip-weighted flops / HBM bytes / collective
bytes, and the three-term roofline.

Why not just ``compiled.cost_analysis()``: XLA's module-level cost
analysis visits each ``while`` body **once**, so a lax.scan over L layers
under-counts flops/bytes by ~L x.  We therefore walk the post-SPMD HLO
text ourselves:

  * ``while`` bodies are weighted by ``backend_config known_trip_count``
    (fallback: the largest constant in the loop condition);
  * flops:   2 * result_elems * contracted_elems for every ``dot`` (and
    dots inside fusions), the near-total of real FLOPs;
  * HBM bytes: sum of result+operand bytes of every top-level instruction
    (fusion internals excluded — a fusion's operands/results are exactly
    its HBM traffic);
  * collective bytes per device: ring-model bytes for all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute.

All shapes in post-SPMD HLO are per-device shards, so every number this
module produces is *per device*.

Hardware model (TPU v5e-like, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Tuple

__all__ = ["HW", "analyze_hlo", "roofline_terms", "parse_hlo_collectives",
           "iter_instructions", "count_ops"]

_DT_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"([a-z][\w\-\$]*)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))")
_TRIP_RE = re.compile(r'known_trip_count[="{\\]+n[\\":]+(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,\s]+)\}")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id",
}


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12     # bf16 FLOP/s per chip
    hbm_bw: float = 819e9          # bytes/s per chip
    ici_bw: float = 50e9           # bytes/s per link (per chip)


def _shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


def _shape_bytes(shape_str: str) -> int:
    if shape_str.startswith("("):
        return sum(_shape_bytes(s.strip())
                   for s in shape_str[1:-1].split(",") if "[" in s)
    dt, dims = _shape_dims(shape_str)
    if dt not in _DT_BYTES:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * _DT_BYTES[dt]


class _Module:
    def __init__(self, hlo: str):
        self.comps: Dict[str, List[str]] = {}
        self.shapes: Dict[str, Dict[str, str]] = {}
        current = None
        for line in hlo.splitlines():
            if current is None:
                m = _HEADER_RE.match(line)
                if m:
                    current = m.group(1)
                    self.comps[current] = []
                    self.shapes[current] = {
                        pm.group(1): pm.group(2)
                        for pm in _PARAM_RE.finditer(m.group(2))
                    }
            else:
                if line.strip() == "}":
                    current = None
                    continue
                self.comps[current].append(line)
                mi = _INSTR_RE.match(line)
                if mi:
                    self.shapes[current][mi.group(1)] = mi.group(2)
        self.entry = next((c for c in self.comps if "main" in c),
                          next(iter(self.comps), None))

    def trip_count(self, line: str) -> int:
        mt = _TRIP_RE.search(line)
        if mt:
            return int(mt.group(1))
        mc = _COND_RE.search(line)
        trip = 1
        if mc:
            for cl in self.comps.get(mc.group(1), []):
                for c in re.findall(r"constant\((\d+)\)", cl):
                    trip = max(trip, int(c))
        return trip


def iter_instructions(hlo: str):
    """Flat iterator over ``(computation, name, shape, op, line)`` for
    every parsed instruction in the module, fusion/while bodies included.

    The shared walking idiom: ``analyze_hlo`` below recurses the same
    parse for trip-weighted cost, and ``repro.launch.audit`` uses this
    flat view to cross-check the jaxpr dispatch census against what XLA
    actually compiled.
    """
    mod = _Module(hlo)
    for comp, lines in mod.comps.items():
        for line in lines:
            mi = _INSTR_RE.match(line)
            if mi:
                name, shape, op = mi.groups()
                yield comp, name, shape, op, line


def count_ops(hlo: str, ops: Tuple[str, ...] = ("dot", "divide")
              ) -> Dict[str, int]:
    """Static opcode census over all computations (not trip-weighted).

    A ``while`` body counts once regardless of trip count — the census
    answers "how many distinct dot/divide sites did XLA emit", the same
    granularity as the jaxpr layer's per-eqn count.
    """
    out = {op: 0 for op in ops}
    for _, _, _, op, _ in iter_instructions(hlo):
        if op in out:
            out[op] += 1
    return out


def analyze_hlo(hlo: str) -> dict:
    """Returns per-device {"flops", "hbm_bytes", "collectives": {...}}."""
    mod = _Module(hlo)
    flops_memo: Dict[str, float] = {}
    bytes_memo: Dict[str, float] = {}
    coll_memo: Dict[str, Dict[str, float]] = {}

    def dot_flops(comp: str, line: str, result_shape: str) -> float:
        _, rdims = _shape_dims(result_shape)
        relems = 1
        for d in rdims:
            relems *= d
        ops = _OPERAND_RE.findall(line[line.index("("):])
        k = 1
        if ops:
            lhs_shape = mod.shapes[comp].get(ops[0], "")
            _, ldims = _shape_dims(lhs_shape)
            mcon = _CONTRACT_RE.search(line)
            if mcon and ldims:
                for d in mcon.group(1).split(","):
                    if d:
                        k *= ldims[int(d)]
        return 2.0 * relems * k

    def walk(comp: str, depth: int = 0) -> Tuple[float, float, Dict[str, float]]:
        if comp in flops_memo:
            return flops_memo[comp], bytes_memo[comp], coll_memo[comp]
        fl, by = 0.0, 0.0
        co = {k: 0.0 for k in _COLLECTIVES}
        if depth > 16 or comp not in mod.comps:
            return fl, by, co
        flops_memo[comp], bytes_memo[comp], coll_memo[comp] = fl, by, co
        for line in mod.comps[comp]:
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, shape, op = mi.groups()
            base_op = op.replace("-start", "").replace("-done", "")
            # ---- flops
            if op in ("dot", "convolution"):
                fl += dot_flops(comp, line, shape)
            # ---- collectives (count -start once, skip -done)
            if base_op in _COLLECTIVES and not op.endswith("-done"):
                r = _shape_bytes(shape)
                g = 2
                mg = _GROUPS_RE.search(line)
                if mg:
                    g = max(2, len(mg.group(1).split(",")))
                if base_op == "all-gather":
                    co[base_op] += r * (g - 1) / g
                elif base_op == "all-reduce":
                    co[base_op] += 2 * r * (g - 1) / g
                elif base_op == "reduce-scatter":
                    co[base_op] += r * (g - 1)
                elif base_op == "all-to-all":
                    co[base_op] += r * (g - 1) / g
                else:
                    co[base_op] += r
            # ---- HBM bytes: result + operands of top-level instructions
            if op not in _SKIP_BYTES_OPS:
                b = _shape_bytes(shape)
                for opnd in _OPERAND_RE.findall(line[line.index("("):line.find(")")+1]):
                    b += _shape_bytes(mod.shapes[comp].get(opnd, ""))
                by += b
            # ---- recursion
            if op == "while":
                mb = _BODY_RE.search(line)
                if mb:
                    trip = mod.trip_count(line)
                    f2, b2, c2 = walk(mb.group(1), depth + 1)
                    fl += f2 * trip
                    by += b2 * trip
                    for k, v in c2.items():
                        co[k] += v * trip
            elif op == "fusion":
                mcall = _CALL_RE.search(line)
                if mcall:  # flops only — fusion internals are not HBM traffic
                    f2, _, c2 = walk(mcall.group(1), depth + 1)
                    fl += f2
                    for k, v in c2.items():
                        co[k] += v
            elif op in ("call", "conditional", "custom-call"):
                for mcall in re.finditer(r"(?:calls|branch_computations=\{)%?([\w\.\-]+)",
                                         line):
                    f2, b2, c2 = walk(mcall.group(1), depth + 1)
                    fl += f2
                    by += b2
                    for k, v in c2.items():
                        co[k] += v
        flops_memo[comp], bytes_memo[comp], coll_memo[comp] = fl, by, co
        return fl, by, co

    if mod.entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0,
                "collectives": {k: 0.0 for k in _COLLECTIVES} | {"total": 0.0}}
    fl, by, co = walk(mod.entry)
    co = dict(co)
    co["total"] = sum(co[k] for k in _COLLECTIVES)
    return {"flops": fl, "hbm_bytes": by, "collectives": co}


def parse_hlo_collectives(hlo: str) -> Dict[str, float]:
    return analyze_hlo(hlo)["collectives"]


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: HW = HW()) -> dict:
    """Three roofline terms in seconds (everything per device).

    compute = flops/peak; memory = HBM bytes/BW; collective = bytes/link BW.
    """
    compute_s = flops_per_dev / hw.peak_flops
    memory_s = bytes_per_dev / hw.hbm_bw
    collective_s = coll_bytes_per_dev / hw.ici_bw
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }
