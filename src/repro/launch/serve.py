"""Serving launcher: batched generation with optional RAPID arithmetic.

``python -m repro.launch.serve --arch yi_6b --reduced --approx``

``--continuous`` swaps the fixed-slot lockstep engine for the
continuous-batching one (paged KV, chunked prefill, slot recycling,
per-request streaming); greedy outputs match per request.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import ARCH_IDS, RAPID, get_config
from repro.launch.backend_args import add_backend_args, apply_backend_args
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--approx", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: paged KV + chunked prefill "
                         "+ slot recycling (repro.serve.scheduler)")
    add_backend_args(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.approx:
        cfg = cfg.with_(approx=RAPID)
    cfg = apply_backend_args(cfg, args)
    assert cfg.family not in ("encdec", "vlm"), \
        "serve demo targets pure-text archs (frontend stubs need batches)"

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.continuous:
        engine = ContinuousServeEngine(
            model, params, ParallelCtx(), n_slots=args.batch,
            max_len=args.cache, temperature=args.temperature)
    else:
        engine = ServeEngine(model, params, ParallelCtx(),
                             cache_n=args.cache,
                             temperature=args.temperature)
    prompts = [[1 + (i + j) % 32 for j in range(5 + i)]
               for i in range(args.batch)]
    t0 = time.time()
    out = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in out)
    for i, o in enumerate(out):
        print(f"req{i}: {o}")
    mode = "continuous" if args.continuous else "fixed-slot"
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s, {mode}, "
          f"approx={'RAPID' if args.approx else 'exact'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
