"""Shared launcher plumbing for backend-registry CLI flags.

Every launcher (train / serve / dryrun) exposes the same two flags:

  ``--backend NAME``            one registry name for every site;
  ``--site-backend SITE=NAME``  repeatable per-site override (sites:
                                mlp / attn_proj / logits / norm /
                                softmax / default)

so one command line can mix execution paths — e.g. pallas fused-tail
MLP matmuls with partitioner-visible jnp logits::

  python -m repro.launch.serve --arch yi_6b --reduced --approx \
      --backend pallas --site-backend logits=jnp
"""
from __future__ import annotations

from typing import Iterable

from repro.configs.base import ModelConfig

__all__ = ["add_backend_args", "apply_backend_args", "parse_site_backends"]


def add_backend_args(ap) -> None:
    """Attach the shared --backend / --site-backend flags to a parser."""
    ap.add_argument("--backend", default=None,
                    help="approximate-arithmetic backend registry name "
                         "for every site (jnp | pallas | pallas-interpret"
                         " | auto)")
    ap.add_argument("--site-backend", action="append", default=[],
                    metavar="SITE=NAME",
                    help="per-site backend override (site: mlp | "
                         "attn_proj | logits | norm | softmax | default);"
                         " repeatable, e.g. --site-backend mlp=pallas "
                         "--site-backend logits=jnp")


def _valid_backend_names():
    from repro.core.backend import AUTO_HW, available_backends

    return available_backends() + ("auto", AUTO_HW)


def parse_site_backends(entries: Iterable[str]) -> dict:
    """Parse repeated ``SITE=NAME`` strings into a site->backend map.

    Both halves are validated here so a flag typo dies as a clean
    one-line CLI error instead of a framework traceback (a bad site used
    to surface as a KeyError from ``ApproxConfig.__post_init__``, a bad
    name only at the first dispatch inside tracing).
    """
    from repro.configs.base import BACKEND_SITES

    table = {}
    sites = BACKEND_SITES + ("default",)
    for entry in entries:
        site, sep, name = entry.partition("=")
        if not sep or not site or not name:
            raise SystemExit(
                f"--site-backend expects SITE=NAME, got {entry!r}")
        if site not in sites:
            raise SystemExit(
                f"--site-backend: unknown site {site!r}; have {sites}")
        if name not in _valid_backend_names():
            raise SystemExit(
                f"--site-backend: unknown backend {name!r}; have "
                f"{_valid_backend_names()}")
        table[site] = name
    return table


def apply_backend_args(cfg: ModelConfig, args) -> ModelConfig:
    """Fold the parsed flags into the config's per-site backend map.

    ``--backend`` resets every site first; ``--site-backend`` entries
    then override individual sites (both validated against the site
    table / registry before they touch the config).
    """
    backend = getattr(args, "backend", None)
    if backend:
        if backend not in _valid_backend_names():
            raise SystemExit(
                f"--backend: unknown backend {backend!r}; have "
                f"{_valid_backend_names()}")
        cfg = cfg.with_backend(backend)
    sites = parse_site_backends(getattr(args, "site_backend", []) or [])
    if sites:
        cfg = cfg.with_site_backends(sites)
    return cfg
