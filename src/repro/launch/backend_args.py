"""Shared launcher plumbing for backend-registry CLI flags.

Every launcher (train / serve / dryrun) exposes the same two flags:

  ``--backend NAME``            one registry name for every site;
  ``--site-backend SITE=NAME``  repeatable per-site override (sites:
                                mlp / attn_proj / logits / norm /
                                softmax / default)

so one command line can mix execution paths — e.g. pallas fused-tail
MLP matmuls with partitioner-visible jnp logits::

  python -m repro.launch.serve --arch yi_6b --reduced --approx \
      --backend pallas --site-backend logits=jnp
"""
from __future__ import annotations

from typing import Iterable

from repro.configs.base import ModelConfig

__all__ = ["add_backend_args", "apply_backend_args", "parse_site_backends"]


def add_backend_args(ap) -> None:
    """Attach the shared --backend / --site-backend flags to a parser."""
    ap.add_argument("--backend", default=None,
                    help="approximate-arithmetic backend registry name "
                         "for every site (jnp | pallas | pallas-interpret"
                         " | auto)")
    ap.add_argument("--site-backend", action="append", default=[],
                    metavar="SITE=NAME",
                    help="per-site backend override (site: mlp | "
                         "attn_proj | logits | norm | softmax | default);"
                         " repeatable, e.g. --site-backend mlp=pallas "
                         "--site-backend logits=jnp")


def parse_site_backends(entries: Iterable[str]) -> dict:
    """Parse repeated ``SITE=NAME`` strings into a site->backend map."""
    table = {}
    for entry in entries:
        site, sep, name = entry.partition("=")
        if not sep or not site or not name:
            raise SystemExit(
                f"--site-backend expects SITE=NAME, got {entry!r}")
        table[site] = name
    return table


def apply_backend_args(cfg: ModelConfig, args) -> ModelConfig:
    """Fold the parsed flags into the config's per-site backend map.

    ``--backend`` resets every site first; ``--site-backend`` entries
    then override individual sites (validation of site keys happens in
    ``ApproxConfig``, of registry names at resolve time).
    """
    if getattr(args, "backend", None):
        cfg = cfg.with_backend(args.backend)
    sites = parse_site_backends(getattr(args, "site_backend", []) or [])
    if sites:
        cfg = cfg.with_site_backends(sites)
    return cfg
