"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real training (synthetic or bin corpus) on whatever devices exist.
On the CPU container this trains reduced configs; on a real pod the same
entry point builds the production mesh and shards per parallel/sharding
rules.  ``--approx`` turns on the paper's RAPID arithmetic end to end.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import ARCH_IDS, RAPID, get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.backend_args import add_backend_args, apply_backend_args
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.parallel.sharding import make_rules, named_sharding_tree
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig
from repro.train.trainstep import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU smoke scale)")
    ap.add_argument("--approx", action="store_true",
                    help="enable RAPID approximate mul/div")
    add_backend_args(ap)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.approx:
        cfg = cfg.with_(approx=RAPID)
    cfg = apply_backend_args(cfg, args)

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = make_rules(cfg, multi_pod=args.multi_pod)
    elif len(jax.devices()) > 1:
        mesh = make_local_mesh(data=len(jax.devices()))
        rules = make_rules(cfg)
    else:
        mesh, rules = None, {}
    ctx = ParallelCtx(mesh, rules) if mesh is not None else ParallelCtx()

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    oc = OptConfig(name=cfg.optimizer, lr=args.lr,
                   schedule=cfg.lr_schedule, total_steps=args.steps)
    init_opt, train_step = make_train_step(model, oc, ctx,
                                           microbatches=args.microbatches)
    opt_state = init_opt(params)

    if mesh is not None:
        pspecs = named_sharding_tree(mesh, model.pspecs(rules))
        params = jax.device_put(params, pspecs)

    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    lc = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir, log_every=10)
    state = train_loop(step_fn, params, opt_state, src, lc)
    print(f"final loss: {state.losses[-1]:.4f} "
          f"(first {state.losses[0]:.4f}) over {state.step} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
