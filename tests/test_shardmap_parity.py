"""shard_map-aware pallas backend: EP/TP/FSDP parity sweep.

The tentpole contract: with the "mlp" site on ``pallas-interpret``, the
sharded MoE forward (every dispatch mode: EP weight-gather, EP
all-to-all, token-gather) must equal the single-device oracle — *bit*
equal wherever each device contracts a contiguous K range (EP/TP; the
oracle is the jnp scan at ``chunk=1``, the kernel's slab accumulation
order), and within f32 reduction tolerance where FSDP splits the
contraction dim across ranks (token-gather regroups the K sum).

The in-process sweep needs a multi-device process; CI's ``multidevice``
job provides one via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the tests skip on fewer devices).  One subprocess smoke test stays
unmarked so the plain tier-1 run keeps end-to-end coverage.
"""
import os
import subprocess
import sys
import textwrap
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ApproxConfig, get_config
from repro.core import backend as be
from repro.core.ops import qmatmul, qmatmul_batched
from repro.models import moe
from repro.models.layers import ParallelCtx
from repro.models.moe import moe_ffn, moe_params
from repro.models.params import materialize
from repro.parallel.sharding import make_rules

NDEV = 8

def sweep(fn):
    """The in-process sweep marks: ``multidevice`` (CI job selector),
    ``parity`` (bit-exactness gate family), and the 8-device skip."""
    for mark in (
        pytest.mark.skipif(
            jax.device_count() < NDEV,
            reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"),
        pytest.mark.parity,
        pytest.mark.multidevice,
    ):
        fn = mark(fn)
    return fn


def _moe_cfg(backends):
    # float32 activations keep every cross-device combination (psum /
    # all_to_all scatter-adds of <= k=2 per-token contributions) an IEEE
    # commutative 2-term sum, so the sharded/local comparison is exact.
    return get_config("qwen3_moe_235b_a22b").reduced().with_(
        n_experts=4, experts_per_token=2, d_model=64, d_ff=64,
        vocab_size=512, n_layers=1, dtype="float32", capacity_factor=8.0,
        approx=ApproxConfig(mul_scheme="rapid10", backends=backends))


def _moe_inputs(cfg):
    params = materialize(moe_params(cfg), jax.random.PRNGKey(0), "float32")
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 8, cfg.d_model)),
                    jnp.float32)
    return params, x


def _jnp_oracle(cfg, params, x, monkeypatch):
    """Single-device jnp forward with chunk=1 (the kernel's slab
    accumulation order, see test_backend's bit-exactness notes)."""
    monkeypatch.setattr(moe, "qmatmul_batched",
                        partial(qmatmul_batched, chunk=1))
    out = moe_ffn(x, params, cfg.with_backend("jnp"), ParallelCtx())
    monkeypatch.undo()
    return out


# mesh shape x rule knobs covering the EP/TP dispatch modes: weight-
# gather (seq replicated), all-to-all (sequence sharded on the model
# axis), EP over a different data/model split, and batch-unsharded EP.
EP_TP_SPECS = [
    pytest.param((2, 4), dict(fsdp=False, seq_parallel=False),
                 id="ep-weight-gather-2x4"),
    pytest.param((2, 4), dict(fsdp=False, seq_parallel=True),
                 id="ep-a2a-seq-sharded-2x4"),
    pytest.param((4, 2), dict(fsdp=False, seq_parallel=False),
                 id="ep-weight-gather-4x2"),
    pytest.param((2, 4), dict(fsdp=False, seq_parallel=False,
                              shard_batch=False),
                 id="ep-batch-replicated-2x4"),
]


@sweep
@pytest.mark.parametrize("mesh_shape,rules_kw", EP_TP_SPECS)
def test_moe_sharded_kernel_bitexact_vs_jnp_oracle(mesh_shape, rules_kw,
                                                   monkeypatch):
    """EP/TP shard_map bodies running the pallas kernels on local shards
    reproduce the single-device jnp oracle bit for bit."""
    cfg = _moe_cfg({"mlp": "pallas-interpret", "default": "jnp"})
    params, x = _moe_inputs(cfg)
    oracle = _jnp_oracle(cfg, params, x, monkeypatch)

    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    ctx = ParallelCtx(mesh, make_rules(cfg, **rules_kw))
    out = jax.jit(lambda x, p: moe_ffn(x, p, cfg, ctx))(x, params)
    np.testing.assert_array_equal(
        np.asarray(out).view(np.int32), np.asarray(oracle).view(np.int32))


@sweep
def test_moe_fsdp_token_gather_matches_oracle_to_f32_tolerance(monkeypatch):
    """The FSDP token-gather mode splits the down-projection's K dim
    across ranks, regrouping the f32 reduction — equal to the oracle to
    reduction tolerance, not bitwise."""
    cfg = _moe_cfg({"mlp": "pallas-interpret", "default": "jnp"})
    params, x = _moe_inputs(cfg)
    oracle = _jnp_oracle(cfg, params, x, monkeypatch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ctx = ParallelCtx(mesh, make_rules(cfg, fsdp=True, seq_parallel=False))
    out = jax.jit(lambda x, p: moe_ffn(x, p, cfg, ctx))(x, params)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-4, atol=2e-4)


@sweep
@pytest.mark.parametrize("m,n,k", [
    (16, 64, 64),    # local N = 16 over 4-way TP: heavy lane padding
    (8, 256, 128),   # local N = 64, K one block
    (32, 96, 40),    # unaligned everything
])
def test_tp_matmul_under_shard_map_bitexact(m, n, k):
    """Plain TP: rows sharded on data, columns on model — resolve_spec
    sees the *per-shard* shapes inside the body and the fused epilogue
    stays intact per shard."""
    from jax.sharding import PartitionSpec

    from repro.compat import shard_map

    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    def body(xl, wl, bl):
        return qmatmul(xl, wl, "rapid10", backend="pallas-interpret",
                       bias=bl, activation="silu")

    out = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(PartitionSpec("data", None), PartitionSpec(None, "model"),
                  PartitionSpec("model")),
        out_specs=PartitionSpec("data", "model"), check_vma=False,
    ))(x, w, b)
    ref = qmatmul(x, w, "rapid10", backend="pallas-interpret",
                  bias=b, activation="silu")
    np.testing.assert_array_equal(
        np.asarray(out).view(np.int32), np.asarray(ref).view(np.int32))


@sweep
def test_flash_decode_combine_runs_in_body_and_matches_unsharded():
    """The seq-sharded decode combine now divides inside the manual
    region (fused div kernel per shard); partial-stat psums regroup the
    row sums, so parity with the unsharded path is to f32 tolerance."""
    from repro.models.layers import decode_attention

    rng = np.random.default_rng(3)
    B, H, KV, hd, C = 1, 4, 2, 16, 64
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, C, KV, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, C, KV, hd)), jnp.float32)
    sp = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None, :], (B, C))
    acfg = ApproxConfig(div_scheme="rapid9",
                        backends={"softmax": "pallas-interpret",
                                  "default": "jnp"})

    ref = decode_attention(q, kc, vc, sp, C - 1, 0, acfg)

    mesh = jax.make_mesh((1, NDEV), ("data", "model"))
    ctx = ParallelCtx(mesh, make_rules(None, shard_batch=False,
                                       shard_cache_seq=True))
    sharded = jax.jit(lambda q, kc, vc, sp: decode_attention(
        q, kc, vc, sp, C - 1, 0, acfg, ctx, seq_shard_axis="model"))
    out = sharded(q, kc, vc, sp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # the divide really traces inside the shard_map body as the kernel
    jaxpr = str(jax.make_jaxpr(
        lambda q, kc, vc, sp: decode_attention(
            q, kc, vc, sp, C - 1, 0, acfg, ctx, seq_shard_axis="model"))(
        q, kc, vc, sp))
    assert "shard_map" in jaxpr and "pallas_call" in jaxpr


@sweep
def test_auto_hw_pin_routes_kernels_only_inside_manual_regions(monkeypatch):
    """On a (faked) multi-device TPU, an AUTO_HW-pinned config traces
    the pallas kernels inside the EP shard_map bodies while the same
    config's global-view (mesh-less) forward stays on the jnp oracle —
    the per-call-site routing the tentpole adds."""
    # patch the memoized probe, not jax.default_backend: the kernel
    # wrappers must keep seeing the real CPU platform (interpret mode)
    monkeypatch.setattr(be, "_device_probe", lambda: ("tpu", NDEV))
    monkeypatch.delenv(be.ENV_VAR, raising=False)
    cfg = _moe_cfg("auto")
    pinned = cfg.with_(approx=be.pin_backends(cfg.approx))
    assert pinned.approx.backend_for("mlp") == be.AUTO_HW
    params, x = _moe_inputs(pinned)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh, make_rules(pinned, fsdp=False,
                                       seq_parallel=False))
    sharded = str(jax.make_jaxpr(
        lambda x, p: moe_ffn(x, p, pinned, ctx))(x, params))
    local = str(jax.make_jaxpr(
        lambda x, p: moe_ffn(x, p, pinned, ParallelCtx()))(x, params))
    assert "pallas_call" in sharded
    assert "pallas_call" not in local


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from functools import partial
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ApproxConfig, get_config
    from repro.models import moe
    from repro.models.layers import ParallelCtx
    from repro.models.moe import moe_ffn, moe_params
    from repro.models.params import materialize
    from repro.parallel.sharding import make_rules
    from repro.core.ops import qmatmul_batched

    cfg = get_config("qwen3_moe_235b_a22b").reduced().with_(
        n_experts=4, experts_per_token=2, d_model=64, d_ff=64,
        vocab_size=512, n_layers=1, dtype="float32", capacity_factor=8.0,
        approx=ApproxConfig(mul_scheme="rapid10",
                            backends={"mlp": "pallas-interpret",
                                      "default": "jnp"}))
    params = materialize(moe_params(cfg), jax.random.PRNGKey(0), "float32")
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 8, 64)),
                    jnp.float32)

    moe.qmatmul_batched = partial(qmatmul_batched, chunk=1)
    oracle = moe_ffn(x, params, cfg.with_backend("jnp"), ParallelCtx())
    moe.qmatmul_batched = qmatmul_batched

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh, make_rules(cfg, fsdp=False, seq_parallel=False))
    out = jax.jit(lambda x, p: moe_ffn(x, p, cfg, ctx))(x, params)
    assert np.array_equal(np.asarray(out).view(np.int32),
                          np.asarray(oracle).view(np.int32))
    print("OK")
""")


def test_moe_shard_map_kernel_parity_subprocess():
    """Tier-1 coverage on a single-device host: one EP spec, spawned
    with 8 fake XLA devices, sharded pallas-interpret vs the jnp
    oracle, bit-exact."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
