"""The jax-version compat shim: both shard_map signatures, monkeypatched,
plus the real resolution on the installed jax; the manual-mesh (axis-env)
helpers against both API generations (legacy frame stack vs modern
AxisEnv) and against the real shard_map."""
import inspect
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat


def _capture(calls):
    """A fake shard_map core that records how it was invoked."""

    def run(f, **kwargs):
        calls.append(kwargs)
        return f

    return run


def test_adapt_maps_check_vma_to_check_rep():
    calls = []
    run = _capture(calls)

    def legacy(f, *, mesh, in_specs, out_specs, check_rep=True):  # jax 0.4.x
        return run(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_rep)

    wrapped = compat.adapt_shard_map(legacy)
    body = lambda x: x  # noqa: E731
    out = wrapped(body, mesh="MESH", in_specs=("i",), out_specs="o",
                  check_vma=False)
    assert out is body
    assert calls == [{"mesh": "MESH", "in_specs": ("i",), "out_specs": "o",
                      "check_rep": False}]


def test_adapt_passes_check_vma_through_on_modern_jax():
    calls = []
    run = _capture(calls)

    def modern(f, *, mesh, in_specs, out_specs, check_vma=True):  # jax 0.8+
        return run(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=check_vma)

    wrapped = compat.adapt_shard_map(modern)
    wrapped(lambda x: x, mesh="M", in_specs="i", out_specs="o", check_vma=False)
    assert calls[0]["check_vma"] is False


def test_adapt_drops_flag_when_impl_has_neither_kwarg():
    calls = []
    run = _capture(calls)

    def bare(f, *, mesh, in_specs, out_specs):
        return run(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    wrapped = compat.adapt_shard_map(bare)
    wrapped(lambda x: x, mesh="M", in_specs="i", out_specs="o", check_vma=False)
    assert "check_rep" not in calls[0] and "check_vma" not in calls[0]


def test_adapt_omits_flag_when_unset():
    calls = []
    run = _capture(calls)

    def legacy(f, *, mesh, in_specs, out_specs, **kw):
        return run(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    wrapped = compat.adapt_shard_map(legacy)
    wrapped(lambda x: x, mesh="M", in_specs="i", out_specs="o")
    assert "check_rep" not in calls[0] and "check_vma" not in calls[0]


def test_resolve_finds_installed_shard_map():
    impl = compat.resolve_shard_map()
    assert callable(impl)
    params = inspect.signature(impl).parameters
    # whichever jax this is, the shim must know its check kwarg (or lack)
    assert compat._check_kwarg_name(impl) in ("check_vma", "check_rep", None)


def test_shard_map_executes_on_installed_jax():
    """End-to-end: the shim actually runs a shard_map on a 1-device mesh."""
    from jax.sharding import PartitionSpec

    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.arange(8, dtype=jnp.float32)
    out = compat.shard_map(
        lambda v: v * 2.0,
        mesh=mesh,
        in_specs=PartitionSpec("d"),
        out_specs=PartitionSpec("d"),
        check_vma=False,
    )(x)
    np.testing.assert_allclose(np.asarray(out), np.arange(8) * 2.0)


# --------------------------------------------------------------------------
# manual-mesh (axis-env) helpers: both API generations, one contract
# --------------------------------------------------------------------------

class _Frame(SimpleNamespace):
    """Legacy AxisEnvFrame stand-in (jax <= 0.4.35): .name / .size."""


def _legacy_core(frames):
    """A jax.core lookalike exposing only the legacy frame-stack surface."""
    return SimpleNamespace(
        thread_local_state=SimpleNamespace(
            trace_state=SimpleNamespace(axis_env=frames)))


def _modern_core(axis_sizes):
    """A jax.core lookalike exposing only the 0.4.36+/0.8+ get_axis_env."""
    env = SimpleNamespace(axis_sizes=axis_sizes, spmd_axis_names=set())
    return SimpleNamespace(get_axis_env=lambda: env)


def test_axis_sizes_pure_helpers_both_generations():
    frames = [_Frame(name="data", size=4), _Frame(name="model", size=2)]
    legacy = compat.axis_sizes_from_frames(frames)
    modern = compat.axis_sizes_from_env(
        SimpleNamespace(axis_sizes={"data": 4, "model": 2}))
    assert legacy == modern == {"data": 4, "model": 2}
    # empty environments read as "not in a manual region" in both shapes
    assert compat.axis_sizes_from_frames([]) == {}
    assert compat.axis_sizes_from_env(SimpleNamespace(axis_sizes={})) == {}
    assert compat.axis_sizes_from_env(SimpleNamespace()) == {}


def test_axis_sizes_from_frames_skips_unnamed_axes():
    """The no_axis_name sentinel an unnamed vmap pushes is not a manual
    mesh axis and must not count as shard_map evidence."""
    sentinel = object()  # stands in for jax.core.no_axis_name
    frames = [_Frame(name=sentinel, size=3), _Frame(name="model", size=2),
              _Frame(name="dropme", size=None)]
    assert compat.axis_sizes_from_frames(frames) == {"model": 2}


def test_axis_env_reader_identical_across_api_generations():
    """The resolved reader behaves identically whether the core exposes
    the 0.4.x frame stack or the 0.8+ AxisEnv — same sizes, same
    in-region verdict, same local-axis products."""
    sizes = {"data": 4, "model": 2}
    legacy_reader = compat.axis_env_reader_for(
        _legacy_core([_Frame(name=n, size=s) for n, s in sizes.items()]))
    modern_reader = compat.axis_env_reader_for(_modern_core(dict(sizes)))
    assert legacy_reader() == modern_reader() == sizes
    # a core exposing neither surface: never inside a manual region
    assert compat.axis_env_reader_for(SimpleNamespace())() == {}


def test_manual_helpers_through_monkeypatched_modern_core(monkeypatch):
    """axis_env_sizes() reads through jax.core when it exposes the
    modern surface (the 0.8+ shape, exercised on whatever jax is
    installed)."""
    env = SimpleNamespace(axis_sizes={"model": 8}, spmd_axis_names=set())
    monkeypatch.setattr(jax.core, "get_axis_env", lambda: env,
                        raising=False)
    assert compat.axis_env_sizes() == {"model": 8}
    assert compat.in_shard_map()
    assert compat.manual_axis_size("model") == 8
    with pytest.raises(KeyError):
        compat.manual_axis_size("data")


def test_manual_axis_size_products():
    frames = [_Frame(name="data", size=4), _Frame(name="model", size=2)]
    reader = compat.axis_env_reader_for(_legacy_core(frames))
    # product semantics via the pure reader feeding a fake jax.core
    sizes = reader()
    assert sizes["data"] * sizes["model"] == 8


def test_axis_env_on_installed_jax_inside_and_outside_shard_map():
    """The real thing: outside any region the env is empty; inside a
    compat.shard_map body every mesh axis (even 1-sized) is bound, so
    in_shard_map() is True and sizes/products resolve."""
    from jax.sharding import PartitionSpec

    assert compat.axis_env_sizes() == {}
    assert not compat.in_shard_map()

    seen = []
    mesh = jax.make_mesh((1,), ("d",))

    def body(v):
        seen.append((compat.axis_env_sizes(), compat.in_shard_map(),
                     compat.manual_axis_size("d")))
        return v

    compat.shard_map(body, mesh=mesh, in_specs=PartitionSpec("d"),
                     out_specs=PartitionSpec("d"), check_vma=False)(
        jnp.arange(4, dtype=jnp.float32))
    assert seen == [({"d": 1}, True, 1)]
    # and the env unwinds cleanly after the region
    assert compat.axis_env_sizes() == {}
