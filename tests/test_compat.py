"""The jax-version compat shim: both shard_map signatures, monkeypatched,
plus the real resolution on the installed jax."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat


def _capture(calls):
    """A fake shard_map core that records how it was invoked."""

    def run(f, **kwargs):
        calls.append(kwargs)
        return f

    return run


def test_adapt_maps_check_vma_to_check_rep():
    calls = []
    run = _capture(calls)

    def legacy(f, *, mesh, in_specs, out_specs, check_rep=True):  # jax 0.4.x
        return run(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_rep)

    wrapped = compat.adapt_shard_map(legacy)
    body = lambda x: x  # noqa: E731
    out = wrapped(body, mesh="MESH", in_specs=("i",), out_specs="o",
                  check_vma=False)
    assert out is body
    assert calls == [{"mesh": "MESH", "in_specs": ("i",), "out_specs": "o",
                      "check_rep": False}]


def test_adapt_passes_check_vma_through_on_modern_jax():
    calls = []
    run = _capture(calls)

    def modern(f, *, mesh, in_specs, out_specs, check_vma=True):  # jax 0.8+
        return run(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=check_vma)

    wrapped = compat.adapt_shard_map(modern)
    wrapped(lambda x: x, mesh="M", in_specs="i", out_specs="o", check_vma=False)
    assert calls[0]["check_vma"] is False


def test_adapt_drops_flag_when_impl_has_neither_kwarg():
    calls = []
    run = _capture(calls)

    def bare(f, *, mesh, in_specs, out_specs):
        return run(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    wrapped = compat.adapt_shard_map(bare)
    wrapped(lambda x: x, mesh="M", in_specs="i", out_specs="o", check_vma=False)
    assert "check_rep" not in calls[0] and "check_vma" not in calls[0]


def test_adapt_omits_flag_when_unset():
    calls = []
    run = _capture(calls)

    def legacy(f, *, mesh, in_specs, out_specs, **kw):
        return run(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    wrapped = compat.adapt_shard_map(legacy)
    wrapped(lambda x: x, mesh="M", in_specs="i", out_specs="o")
    assert "check_rep" not in calls[0] and "check_vma" not in calls[0]


def test_resolve_finds_installed_shard_map():
    impl = compat.resolve_shard_map()
    assert callable(impl)
    params = inspect.signature(impl).parameters
    # whichever jax this is, the shim must know its check kwarg (or lack)
    assert compat._check_kwarg_name(impl) in ("check_vma", "check_rep", None)


def test_shard_map_executes_on_installed_jax():
    """End-to-end: the shim actually runs a shard_map on a 1-device mesh."""
    from jax.sharding import PartitionSpec

    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.arange(8, dtype=jnp.float32)
    out = compat.shard_map(
        lambda v: v * 2.0,
        mesh=mesh,
        in_specs=PartitionSpec("d"),
        out_specs=PartitionSpec("d"),
        check_vma=False,
    )(x)
    np.testing.assert_allclose(np.asarray(out), np.arange(8) * 2.0)
