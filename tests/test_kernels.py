"""Pallas kernels vs pure-jnp oracles: shape/dtype/scheme sweeps +
property tests (interpret=True on CPU).

The property tests prefer ``hypothesis`` when it is installed; hermetic
environments without it fall back to seeded ``np.random`` sampling of the
same input domains, so tier-1 runs fully offline (the hypothesis-backed
variants carry the ``hypothesis`` pytest marker).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dependency: absent in hermetic environments
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import schemes as S
from repro.core.mitchell import mitchell_div_np, mitchell_mul_np
from repro.core import float_approx as fa
from repro.kernels.log_matmul.ops import log_matmul
from repro.kernels.log_matmul.ref import log_matmul_ref
from repro.kernels.spec import KernelSpec, PipelineSpec
from repro.kernels.rapid_div.ops import rapid_div
from repro.kernels.rapid_div.ref import rapid_div_ref
from repro.kernels.rapid_mul.ops import rapid_mul
from repro.kernels.rapid_mul.ref import rapid_mul_ref


@pytest.mark.parametrize("n_bits", [8, 16])
@pytest.mark.parametrize("scheme", ["mitchell", "rapid3", "rapid10"])
@pytest.mark.parametrize("n", [7, 1000, 4096])
def test_rapid_mul_kernel_bitexact(n_bits, scheme, n):
    rng = np.random.default_rng(n + n_bits)
    a = rng.integers(0, 1 << n_bits, n).astype(np.uint32)
    b = rng.integers(0, 1 << n_bits, n).astype(np.uint32)
    got = np.asarray(rapid_mul(jnp.asarray(a), jnp.asarray(b), scheme, n_bits))
    ref = np.asarray(rapid_mul_ref(jnp.asarray(a), jnp.asarray(b),
                                   S.MUL_SCHEMES[scheme], n_bits))
    oracle = mitchell_mul_np(a, b, S.MUL_SCHEMES[scheme], n_bits)
    # uint32 output saturates where the *approximate* product of near-max
    # operands overshoots 2^32-1 (hardware has a wider output bus there)
    oracle = np.minimum(oracle, np.uint64(0xFFFFFFFF))
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(got.astype(np.uint64), oracle)


@pytest.mark.parametrize("n_bits", [4, 8])
@pytest.mark.parametrize("scheme", ["mitchell", "rapid9"])
@pytest.mark.parametrize("n", [129, 2048])
def test_rapid_div_kernel_bitexact(n_bits, scheme, n, rng):
    a = rng.integers(0, 1 << (2 * n_bits), n).astype(np.uint32)
    b = rng.integers(0, 1 << n_bits, n).astype(np.uint32)
    got = np.asarray(rapid_div(jnp.asarray(a), jnp.asarray(b), scheme, n_bits))
    ref = np.asarray(rapid_div_ref(jnp.asarray(a), jnp.asarray(b),
                                   S.DIV_SCHEMES[scheme], n_bits))
    oracle = mitchell_div_np(a, b, S.DIV_SCHEMES[scheme], n_bits)
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(got.astype(np.uint64), oracle)


@pytest.mark.parametrize("shape", [(8, 16, 8), (33, 70, 17), (128, 300, 64)])
@pytest.mark.parametrize("scheme", ["mitchell", "rapid10"])
def test_log_matmul_kernel_vs_oracle(shape, scheme, rng):
    m, k, n = shape
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    lut = jnp.asarray(fa.mul_lut(scheme))
    got = log_matmul(x, w, scheme, spec=KernelSpec(bm=8, bn=128, bk=128))
    want = log_matmul_ref(x, w, lut)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape", [
    (1, 1, 1),      # everything below one tile
    (3, 5, 2),      # sub-tile M/N/K together
    (5, 130, 7),    # K in (128, 512) and NOT a multiple of the unroll:
                    # the old _pick_blocks kept bk=130, truncated
                    # bk // unroll and silently dropped the K tail
    (24, 136, 12),  # K % 8 == 0 but unaligned to lanes
    (300, 200, 9),  # M above one block with sub-tile N
])
def test_log_matmul_degenerate_shapes_bitexact(shape, rng):
    """Degenerate (smaller-than-tile / unaligned) shapes must clamp the
    block sizes up to hardware minimums and still agree bit-for-bit with
    the chunk=1 jnp scan (single K block after padding)."""
    from repro.core.ops import qmatmul

    m, k, n = shape
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = log_matmul(x, w, "rapid10", interpret=True)
    want = qmatmul(x, w, "rapid10", chunk=1, backend="jnp")
    assert got.shape == (m, n)
    np.testing.assert_array_equal(
        np.asarray(got).view(np.int32), np.asarray(want).view(np.int32))


def test_log_matmul_explicit_blocks_exceed_problem(rng):
    """Explicit block fields with bm/bn/bk larger than the problem dims
    (bm > M): the pad-to-block-grid path must stay bit-exact."""
    from repro.core.ops import qmatmul

    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    got = log_matmul(x, w, "rapid10",
                     spec=KernelSpec(bm=256, bn=256, bk=512),
                     interpret=True)
    want = qmatmul(x, w, "rapid10", chunk=1, backend="jnp")
    assert got.shape == (4, 8)
    np.testing.assert_array_equal(
        np.asarray(got).view(np.int32), np.asarray(want).view(np.int32))


def test_log_matmul_explicit_blocks_over_budget():
    """An oversized explicit block choice fails at call time against the
    same VMEM constant the static auditor (RPD005) ratchets on, instead
    of dying on-device."""
    x = jnp.zeros((8, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(ValueError, match="VMEM budget"):
        log_matmul(x, w, "rapid10", spec=KernelSpec(bm=2048, bn=4096, bk=512),
                   interpret=True)


def test_log_matmul_blocks_tuple_removed(rng):
    """The one-release ``blocks=`` tuple shim is gone: passing it (or a
    tuple as ``spec=``) raises TypeError naming the replacement."""
    from repro.kernels.spec import as_kernel_spec

    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    with pytest.raises(TypeError, match=r"spec=KernelSpec\(bm="):
        log_matmul(x, w, "rapid10", blocks=(8, 128, 128), interpret=True)
    with pytest.raises(TypeError, match=r"spec=KernelSpec\(bm="):
        as_kernel_spec((8, 128, 128))


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_log_matmul_depth_knob_bitexact(depth, rng):
    """The KernelSpec pipeline-depth knob changes the schedule, never
    the numbers: every depth agrees bit-for-bit with the chunk=1 jnp
    scan on a single-K-block problem."""
    from repro.core.ops import qmatmul

    x = jnp.asarray(rng.normal(size=(24, 136)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(136, 40)), jnp.float32)
    got = log_matmul(x, w, "rapid10", interpret=True,
                     spec=KernelSpec(pipeline=PipelineSpec(depth=depth)))
    want = qmatmul(x, w, "rapid10", chunk=1, backend="jnp")
    np.testing.assert_array_equal(
        np.asarray(got).view(np.int32), np.asarray(want).view(np.int32))


def test_pick_blocks_norm_epilogue_rebalance_fits_budget():
    """Norm epilogues force whole padded rows per tile; the rebalanced
    bm/bk must keep the working set inside the auditor's budget even at
    real model widths."""
    from repro.kernels.log_matmul.ops import _check_budget
    from repro.kernels.spec import (_default_matmul_blocks,
                                    _rebalance_norm_matmul)
    from repro.core.backend import Epilogue

    ep = Epilogue(norm="rms", div_scheme="rapid9")

    def rebalanced(m, n, k):
        bm, bn, bk = _default_matmul_blocks(m, n, k)
        return _rebalance_norm_matmul(bm, bn, bk, n)

    for m, n, k in [(8, 4096, 512), (256, 8192, 1024), (1, 3000, 128)]:
        _check_budget(*rebalanced(m, n, k), ep, False, False)  # no raise

    # vocab-width rows can't fit whole in VMEM at the minimum bk of one
    # lane tile: the wrapper must fail fast, not die on-device
    with pytest.raises(ValueError, match="VMEM budget"):
        _check_budget(*rebalanced(1, 50257, 128), ep, False, False)


def test_pick_blocks_hardware_aligned():
    """Blocks are multiples of the f32 tile (8 sublanes / 128 lanes) and
    bk stays a multiple of the unroll factor for every K."""
    from repro.kernels.spec import _default_matmul_blocks

    for m, n, k in [(1, 1, 1), (5, 7, 130), (300, 9, 136), (999, 999, 999)]:
        bm, bn, bk = _default_matmul_blocks(m, n, k)
        assert bm % 8 == 0 and 8 <= bm <= 256
        assert bn % 128 == 0 and 128 <= bn <= 256
        assert bk % 128 == 0 and 128 <= bk <= 512
        assert bk % 8 == 0


def test_log_matmul_error_bound(rng):
    """Dot-product error stays within the per-element PRE (cancellation
    makes it far smaller — the paper's near-zero-bias aggregation claim)."""
    x = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    got = log_matmul(x, w, "rapid10")
    exact = x @ w
    rel = float(jnp.abs(got - exact).mean() / jnp.abs(exact).mean())
    assert rel < 0.037  # well under the elementwise PRE


# --------------------------------------------------------------------------
# property tests: hypothesis when available, seeded np.random fallback.
# The check bodies are shared; only the input generation differs.
# --------------------------------------------------------------------------

def _check_mul_within_pre_bound(a: int, b: int):
    """Property: every 16-bit product is within the scheme PRE of exact."""
    out = float(mitchell_mul_np(np.asarray([a]), np.asarray([b]),
                                S.RAPID10_MUL, 16, quantize=False)[0])
    if a == 0 or b == 0:
        assert out == 0.0
    else:
        assert abs(out / (a * b) - 1.0) < 0.037


def _check_div_within_pre_bound(a: int, b: int):
    out = float(mitchell_div_np(np.asarray([a]), np.asarray([b]),
                                S.RAPID9_DIV, 8, quantize=False)[0])
    if a == 0:
        assert out == 0.0
    else:
        assert abs(out / (a / b) - 1.0) < 0.035


def _check_float_mul_scale_invariant(x: float, y: float):
    """Relative error depends only on mantissas, not exponents."""
    a = np.float32(x)
    b = np.float32(y)
    if not (np.isfinite(a * b) and a > 0 and b > 0 and a * b > 1e-35):
        return
    r1 = float(fa.approx_mul(jnp.float32(a), jnp.float32(b), "rapid5"))
    r2 = float(fa.approx_mul(jnp.float32(a * 4), jnp.float32(b / 2), "rapid5"))
    if np.isfinite(r1) and np.isfinite(r2) and r1 > 0:
        np.testing.assert_allclose(r2 / r1, 2.0, rtol=1e-6)


if HAVE_HYPOTHESIS:

    @pytest.mark.hypothesis
    @settings(max_examples=200, deadline=None)
    @given(a=st.integers(0, 2**16 - 1), b=st.integers(0, 2**16 - 1))
    def test_prop_mul_within_pre_bound(a, b):
        _check_mul_within_pre_bound(a, b)

    @pytest.mark.hypothesis
    @settings(max_examples=200, deadline=None)
    @given(a=st.integers(0, 2**16 - 1), b=st.integers(1, 2**8 - 1))
    def test_prop_div_within_pre_bound(a, b):
        _check_div_within_pre_bound(a, b)

    @pytest.mark.hypothesis
    @settings(max_examples=100, deadline=None)
    @given(st.floats(1e-20, 1e20), st.floats(1e-20, 1e20))
    def test_prop_float_mul_scale_invariant(x, y):
        _check_float_mul_scale_invariant(x, y)

else:

    def test_prop_mul_within_pre_bound():
        r = np.random.default_rng(1234)
        pairs = r.integers(0, 1 << 16, size=(200, 2))
        for a, b in np.vstack([pairs, [[0, 7], [7, 0], [0, 0]]]):
            _check_mul_within_pre_bound(int(a), int(b))

    def test_prop_div_within_pre_bound():
        r = np.random.default_rng(1234)
        a = r.integers(0, 1 << 16, size=200)
        b = r.integers(1, 1 << 8, size=200)
        _check_div_within_pre_bound(0, 3)
        for ai, bi in zip(a, b):
            _check_div_within_pre_bound(int(ai), int(bi))

    def test_prop_float_mul_scale_invariant():
        r = np.random.default_rng(1234)
        exps = r.uniform(-20, 20, size=(100, 2))
        for ex, ey in exps:
            _check_float_mul_scale_invariant(10.0 ** ex, 10.0 ** ey)
