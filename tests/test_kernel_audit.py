"""Layer-3 kernel geometry audit: capture shim, one broken fixture per
RPD005-008 checker, write-discipline analysis, full-registry sweep, and
the kernel section of the baseline ratchet."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import findings as F
from repro.analysis.capture import CapturedCall, SpecInfo, capture_pallas_calls
from repro.analysis.findings import Finding
from repro.analysis.kernel_audit import (
    analyze_kernel_writes,
    audit_call,
    iter_variants,
    pipeline_report_doc,
    registry_coverage,
    run_kernel_audit,
)
from repro.kernels import budget

jax.config.update("jax_platform_name", "cpu")


def rules_of(findings):
    return sorted({f.rule for f in findings})


# --------------------------------------------------------------------------
# capture shim
# --------------------------------------------------------------------------

def test_capture_records_geometry():
    """The shim records grid / BlockSpecs / dimension_semantics /
    memory spaces / scratch from an unmodified wrapper call, with no
    TPU and no compilation.  The default dispatch is the pipelined
    formulation: a (mi, ni) grid with x / w in ANY memory, the K scan
    and its depth-deep VMEM scratch inside the kernel."""
    from repro.kernels.log_matmul.ops import log_matmul

    x = jnp.ones((8, 16), jnp.float32)
    w = jnp.ones((16, 8), jnp.float32)
    with capture_pallas_calls() as calls:
        out = log_matmul(x, w, "rapid10", interpret=False)
    assert len(calls) == 1
    c = calls[0]
    assert len(c.grid) == 2                      # (mi, ni): kk is in-kernel
    assert c.kernel_kwargs.get("depth") == budget.PIPELINE_BUFFERS
    assert c.dimension_semantics == ("parallel", "parallel")
    assert len(c.in_specs) >= 3                  # x, w, lut
    assert len(c.out_specs) == 1
    assert c.in_specs[0].memory_space == "any"   # x: manual DMA
    assert c.in_specs[1].memory_space == "any"   # w: manual DMA
    assert c.out_specs[0].memory_space is None   # out: grid-staged VMEM
    blk = c.in_specs[0].block()
    assert blk[-1] % budget.LANE == 0            # padded K rides the lanes
    # x / w scratch rotations + one DMA semaphore pair, all depth-deep
    arrays = [s for s in c.scratch_shapes if s["dtype"] != "dma_sem"]
    sems = [s for s in c.scratch_shapes if s["dtype"] == "dma_sem"]
    assert len(arrays) == 2 and len(sems) == 2
    assert all(s["shape"][0] == budget.PIPELINE_BUFFERS
               for s in c.scratch_shapes)
    # the fake returns zeros of the declared out shape
    assert out.shape == (8, 8) and not np.asarray(out).any()


def test_capture_depth1_takes_grid_formulation():
    """depth=1 routes to the legacy (mi, ni, kk) grid kernel — the
    KernelSpec depth knob selects the formulation, not just a size."""
    from repro.kernels.log_matmul.ops import log_matmul
    from repro.kernels.spec import KernelSpec, PipelineSpec

    x = jnp.ones((8, 16), jnp.float32)
    w = jnp.ones((16, 8), jnp.float32)
    with capture_pallas_calls() as calls:
        log_matmul(x, w, "rapid10", interpret=False,
                   spec=KernelSpec(pipeline=PipelineSpec(depth=1)))
    (c,) = calls
    assert len(c.grid) == 3                      # (mi, ni, kk)
    assert c.dimension_semantics[:2] == ("parallel", "parallel")
    assert not c.scratch_shapes
    assert all(s.memory_space is None for s in c.in_specs)


def test_capture_does_not_pollute_jit_cache(rng):
    """A *real* interpret run at the same shapes after a capture must
    not replay the fake's zeros (shim runs under jax.disable_jit)."""
    from repro.kernels.log_matmul.ops import log_matmul

    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    with capture_pallas_calls():
        log_matmul(x, w, "rapid10", interpret=False)
    real = np.asarray(log_matmul(x, w, "rapid10", interpret=True))
    assert real.any(), "real run after capture returned the fake's zeros"
    np.testing.assert_allclose(real, np.asarray(x) @ np.asarray(w),
                               rtol=0.2, atol=0.2)


# --------------------------------------------------------------------------
# synthetic fixtures: one clean, one broken per checker
# --------------------------------------------------------------------------

def _kernel_plain(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _kernel_accum(x_ref, o_ref, *, nk):
    from jax.experimental import pallas as pl

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...]


def _spec(name, shape, block, imap, dtype="float32", itemsize=4):
    return SpecInfo(name=name, shape=shape, dtype=dtype, itemsize=itemsize,
                    block_shape=block, index_map=imap)


def _call(grid, in_specs, out_specs, dims=None, kernel=_kernel_plain,
          aliases=None):
    return CapturedCall(
        kernel=kernel, kernel_name=getattr(kernel, "__name__", "k"),
        kernel_file="src/repro/kernels/fake.py", kernel_kwargs={},
        grid=tuple(grid), in_specs=list(in_specs), out_specs=list(out_specs),
        dimension_semantics=dims, input_output_aliases=aliases)


def test_known_good_geometry_is_clean():
    call = _call(
        grid=(2, 2),
        in_specs=[_spec("in0", (256, 256), (128, 128), lambda i, j: (i, j))],
        out_specs=[_spec("out0", (256, 256), (128, 128),
                         lambda i, j: (i, j))],
        dims=("parallel", "parallel"))
    findings, rep = audit_call(call, "fix/good", "fixture")
    assert findings == []
    assert rep["double_buffer_safe"] is True
    assert rep["write_discipline"] == "single-visit"
    assert rep["output_revisit_dims"] == {"out0": []}


def test_rpd005_over_budget_tile():
    """A grid-varying (4096, 4096) f32 block is 64 MiB before double
    buffering — far past the 16 MiB budget."""
    call = _call(
        grid=(2,),
        in_specs=[_spec("in0", (8192, 4096), (4096, 4096),
                        lambda i: (i, 0))],
        out_specs=[_spec("out0", (16, 128), (8, 128), lambda i: (i, 0))],
        dims=("arbitrary",))
    findings, rep = audit_call(call, "fix/overbudget", "fixture")
    assert rules_of(findings) == ["RPD005"]
    assert rep["working_set_bytes"] > rep["vmem_budget_bytes"]
    assert rep["double_buffer_safe"] is False


def test_rpd006_misaligned_lane_block():
    """Lane dim 64: neither a multiple of 128 nor the full array dim —
    the exact bug class the auditor caught live in the rowbcast
    denominator spec (1-D (bm,) block on the lane axis)."""
    call = _call(
        grid=(4,),
        in_specs=[_spec("in0", (8, 256), (8, 64), lambda i: (0, i))],
        out_specs=[_spec("out0", (32, 256), (8, 256), lambda i: (i, 0))],
        dims=("arbitrary",))
    findings, _ = audit_call(call, "fix/misaligned", "fixture")
    assert rules_of(findings) == ["RPD006"]


def test_rpd006_tail_block_not_dividing():
    call = _call(
        grid=(2,),
        in_specs=[_spec("in0", (8, 384), (8, 256), lambda i: (0, i))],
        out_specs=[_spec("out0", (16, 128), (8, 128), lambda i: (i, 0))],
        dims=("arbitrary",))
    findings, _ = audit_call(call, "fix/tail", "fixture")
    assert any("does not divide" in f.msg for f in findings)
    assert rules_of(findings) == ["RPD006"]


def test_rpd007_non_surjective_index_map():
    """Only 2 of 4 output blocks are ever visited: silent data drop."""
    call = _call(
        grid=(2,),
        in_specs=[_spec("in0", (256, 128), (128, 128), lambda i: (i, 0))],
        out_specs=[_spec("out0", (512, 128), (128, 128),
                         lambda i: (i, 0))],
        dims=("arbitrary",))
    findings, rep = audit_call(call, "fix/nonsurjective", "fixture")
    assert rules_of(findings) == ["RPD007"]
    assert any("never visited" in f.msg for f in findings)
    assert rep["double_buffer_safe"] is False


def test_rpd007_index_map_out_of_range():
    call = _call(
        grid=(4,),
        in_specs=[_spec("in0", (256, 128), (128, 128), lambda i: (i, 0))],
        out_specs=[_spec("out0", (128, 128), (128, 128),
                         lambda i: (0, 0))],
        dims=("arbitrary",))
    findings, _ = audit_call(call, "fix/oob", "fixture")
    assert "RPD007" in rules_of(findings)
    assert any("leaves the array" in f.msg for f in findings)


def test_rpd008_revisit_on_parallel_dim():
    """Output tile revisited across a dim declared 'parallel': Mosaic
    may run those grid steps concurrently -> write race."""
    call = _call(
        grid=(2, 2),
        in_specs=[_spec("in0", (256, 256), (128, 128), lambda i, j: (i, j))],
        out_specs=[_spec("out0", (128, 128), (128, 128),
                         lambda i, j: (0, 0))],
        dims=("parallel", "arbitrary"),
        kernel=None)  # source unavailable -> also unproven discipline
    findings, rep = audit_call(call, "fix/parallelrace", "fixture")
    assert rules_of(findings) == ["RPD008"]
    assert any("parallel" in f.msg for f in findings)
    assert rep["double_buffer_safe"] is False


def test_rpd008_unguarded_assign_on_revisit():
    call = _call(
        grid=(2,),
        in_specs=[_spec("in0", (256, 128), (128, 128), lambda i: (i, 0))],
        out_specs=[_spec("out0", (128, 128), (128, 128), lambda i: (0, 0))],
        dims=("arbitrary",), kernel=_kernel_plain)
    findings, rep = audit_call(call, "fix/raced", "fixture")
    assert rules_of(findings) == ["RPD008"]
    assert rep["write_discipline"] == "raced"


def test_rpd008_guarded_accumulate_is_clean():
    call = _call(
        grid=(2,),
        in_specs=[_spec("in0", (256, 128), (128, 128), lambda i: (i, 0))],
        out_specs=[_spec("out0", (128, 128), (128, 128), lambda i: (0, 0))],
        dims=("arbitrary",), kernel=_kernel_accum)
    findings, rep = audit_call(call, "fix/accum", "fixture")
    assert findings == []
    assert rep["write_discipline"] == "accumulate+first/last-guard"
    assert rep["double_buffer_safe"] is True


def test_analyze_kernel_writes_guard_env():
    """Guard predicates evaluate against functools.partial keywords
    (pl.program_id(0) == nk - 1 with nk bound at dispatch time)."""
    import functools

    def k(x_ref, o_ref, *, nk):
        from jax.experimental import pallas as pl

        @pl.when(pl.program_id(0) == nk - 1)
        def _fin():
            o_ref[...] = x_ref[...]

    writes = analyze_kernel_writes(functools.partial(k, nk=4))
    (w,) = [w for w in writes if w.target == "o_ref"]
    assert w.kind == "assign"
    assert w.guarded_visit(0, first=0, last=3)
    assert not w.guarded_visit(0, first=0, last=7)
    assert analyze_kernel_writes(None) is None


# --------------------------------------------------------------------------
# full sweep: every registered family x shape class audits clean
# --------------------------------------------------------------------------

def test_full_kernel_audit_is_clean():
    findings, reports = run_kernel_audit()
    assert findings == [], [f"{f.rule} {f.entry}: {f.msg}" for f in findings]
    assert len(reports) >= len(iter_variants())
    assert all(r["double_buffer_safe"] for r in reports)
    families = {r["family"] for r in reports}
    assert {"log_matmul", "fused_softmax", "fused_rms", "fused_div_eltwise",
            "fused_div_rowbcast", "flash_attn", "rapid_mul",
            "rapid_div"} <= families
    # the pinned depth-1 deep-K class is the one place the race checker
    # is live (pipelined variants fold the K scan inside the kernel)
    deep = [r for r in reports
            if r["variant"].startswith("log_matmul/deepk2048/plain")]
    assert deep and all(
        r["write_discipline"] == "accumulate+first/last-guard"
        and r["output_revisit_dims"]["out0"] for r in deep)


def test_pipelined_variants_fit_budget_at_pipeline_depth():
    """Every manual-pipeline variant audits within VMEM_BUDGET_BYTES at
    PIPELINE_BUFFERS depth (or deeper), scratch included — the RPD005
    guarantee the KernelSpec depth knob must not break."""
    _, reports = run_kernel_audit()
    piped = [r for r in reports if r["pipeline_depth"] >= 2]
    assert piped, "no pipelined variants in the sweep"
    deep_enough = [r for r in piped
                   if r["pipeline_depth"] >= budget.PIPELINE_BUFFERS]
    assert deep_enough
    for r in piped:
        assert r["scratch_bytes"] > 0, r["variant"]
        assert r["working_set_bytes"] <= r["vmem_budget_bytes"], r["variant"]
        anys = [o for o in r["operands"] if o["memory_space"] == "any"]
        assert anys, r["variant"]
        assert all(o["vmem_bytes"] == 0 for o in anys), r["variant"]


def test_registry_coverage_complete():
    cover = registry_coverage()
    assert cover, "dispatch_signature('pallas') returned no families"
    missing = [fam for fam, kfams in cover.items() if not kfams]
    assert not missing, f"registry families with no audited kernel: {missing}"


def test_committed_pipeline_report_covers_all_variants():
    path = os.path.join(os.path.dirname(__file__), "..",
                        "PIPELINE_REPORT.json")
    with open(path) as fh:
        doc = json.load(fh)
    committed = {k["variant"] for k in doc["kernels"]}
    expected = {vid for vid, _, _ in iter_variants()}
    # every registered variant appears (multi-call variants commit as id#N)
    missing = {v for v in expected
               if v not in committed
               and not any(c.startswith(v + "#") for c in committed)}
    assert not missing, f"PIPELINE_REPORT.json is stale; missing {missing}"
    assert all(k["double_buffer_safe"] for k in doc["kernels"])
    assert pipeline_report_doc([])["version"] == doc["version"]


# --------------------------------------------------------------------------
# ratchet: kernel section of AUDIT_baseline.json
# --------------------------------------------------------------------------

def _kf(rule, entry, primitive, file="src/repro/kernels/a.py", msg="m"):
    return Finding(layer="kernel", rule=rule, file=file, line=0, msg=msg,
                   entry=entry, primitive=primitive)


def test_kernel_finding_key_is_pin_independent():
    """Keys carry no file/line so the two CI jax pins ratchet against
    one committed baseline even if kernel sources shift lines."""
    a = _kf("RPD005", "log_matmul/square512/plain", "kernel",
            file="src/repro/kernels/log_matmul/log_matmul.py")
    b = _kf("RPD005", "log_matmul/square512/plain", "kernel",
            file="/other/checkout/log_matmul.py", msg="different text")
    assert a.key() == b.key()
    res = F.compare([a], [b])
    assert res.ok and not res.new and not res.stale


def test_kernel_section_roundtrip_and_ratchet(tmp_path):
    path = str(tmp_path / "baseline.json")
    known = _kf("RPD006", "fix/x", "in0")
    F.dump_report(path, [], [], kernel_findings=[known])
    loaded = F.load_baseline(path)
    assert [f.key() for f in loaded] == [known.key()]
    assert F.compare([known], loaded).ok
    novel = _kf("RPD008", "fix/y", "out0")
    res = F.compare([known, novel], loaded)
    assert not res.ok and [f.key() for f in res.new] == [novel.key()]


def test_prune_stale_rewrites_baseline(tmp_path):
    path = str(tmp_path / "baseline.json")
    keep = _kf("RPD006", "fix/keep", "in0")
    gone = _kf("RPD005", "fix/gone", "kernel")
    F.dump_report(path, [], [], kernel_findings=[keep, gone])
    removed = F.prune_stale(path, [keep])
    assert removed == 1
    assert [f.key() for f in F.load_baseline(path)] == [keep.key()]
    assert F.prune_stale(path, [keep]) == 0  # idempotent, no rewrite
