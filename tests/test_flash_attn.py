"""Fused flash-decode attention kernel vs the jnp reference.

The kernel replaces the separate score-matmul + mask + softmax-stats +
value-matmul + combine passes of ``models/layers.py::decode_attention``
with one pipelined Pallas kernel.  Contract points under test: exact
agreement with the reference when the whole cache fits one chunk (the
schedules coincide), tight allclose across chunk boundaries (online
max reassociates), the empty-slot / causality / sliding-window masks,
vector and scalar query positions, the RAPID divider combine, the
KernelSpec depth / chunk knobs, and registry dispatch through
``core.ops.qdecode_attn``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import flash_decode_attn
from repro.kernels.flash_attn.ref import canon_posq, decode_attn_ref
from repro.kernels.spec import KernelSpec, PipelineSpec

jax.config.update("jax_platform_name", "cpu")


def _case(rng, b=2, c=192, kv=2, g=4, hd=64, maxpos=300):
    qf = jnp.asarray(rng.normal(size=(b, kv, g, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, c, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, c, kv, hd)), jnp.float32)
    sp = jnp.asarray(rng.integers(0, maxpos, size=(b, c)), jnp.int32)
    return qf, k, v, sp


def _spec(depth=None, bc=None):
    pipe = PipelineSpec(depth=depth) if depth else PipelineSpec()
    return KernelSpec(bk=bc, pipeline=pipe)


def test_single_chunk_bitexact_vs_ref(rng):
    """Cache fits one 128-slot chunk: the online schedule degenerates to
    the reference's global max/sum, so parity is bit-for-bit."""
    qf, k, v, sp = _case(rng, c=128)
    for scheme in (None, "rapid9"):
        ref = decode_attn_ref(qf, k, v, sp, 200, 0, scheme)
        got = flash_decode_attn(qf, k, v, sp, 200, 0, scheme,
                                interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got).view(np.int32), np.asarray(ref).view(np.int32))


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("scheme", [None, "rapid9", "mitchell"])
def test_multi_chunk_allclose_vs_ref(scheme, depth, rng):
    qf, k, v, sp = _case(rng, c=300)
    ref = decode_attn_ref(qf, k, v, sp, 250, 0, scheme)
    got = flash_decode_attn(qf, k, v, sp, 250, 0, scheme,
                            spec=_spec(depth=depth), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_vector_positions_and_window(rng):
    """Per-batch query positions and a sliding window must mask exactly
    like the reference (window excludes slots <= pos - window)."""
    qf, k, v, sp = _case(rng, b=3, c=160, maxpos=500)
    pos = jnp.asarray([100, 300, 450], jnp.int32)
    for window in (0, 64):
        ref = decode_attn_ref(qf, k, v, sp, pos, window, "rapid9")
        got = flash_decode_attn(qf, k, v, sp, pos, window, "rapid9",
                                interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6)


def test_empty_and_future_slots_are_ignored(rng):
    """INT32_MAX (ring-cache empty) and future-position slots carry
    garbage values; the causality mask must keep them out of the
    softmax stats — including the padded tail the wrapper adds."""
    qf, k, v, sp = _case(rng, c=100)  # pads to 128: tail slots
    empty = jnp.iinfo(jnp.int32).max
    sp = sp.at[:, 5].set(empty).at[:, 17].set(250)  # pos below excludes both
    k = k.at[:, 5].set(1e9).at[:, 17].set(1e9)
    v = v.at[:, 5].set(1e9).at[:, 17].set(1e9)
    ref = decode_attn_ref(qf, k, v, sp, 200, 0, None)
    got = flash_decode_attn(qf, k, v, sp, 200, 0, None, interpret=True)
    assert bool(jnp.isfinite(got).all())
    # padding widens the row reduction (100 -> 128 lanes) so the sum
    # tree reassociates vs the unpadded reference: ULP-level, not 1e9
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-6, atol=2e-7)


def test_no_visible_slots_hits_floor(rng):
    """pos below every slot position: l clamps to the softmax floor and
    the output is finite zeros, not NaN from 0/0."""
    qf, k, v, sp = _case(rng, c=128, maxpos=300)
    out = flash_decode_attn(qf, k, v, sp + 1000, 200, 0, None,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_cache_chunk_knob_changes_schedule_not_numbers(rng):
    """spec.bk picks the cache chunk; 256 covers the padded cache in one
    chunk so it must be bit-exact vs the 2-chunk default schedule's
    reference, and reject non-lane multiples."""
    qf, k, v, sp = _case(rng, c=256)
    ref = decode_attn_ref(qf, k, v, sp, 250, 0, None)
    got = flash_decode_attn(qf, k, v, sp, 250, 0, None,
                            spec=_spec(bc=256), interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got).view(np.int32), np.asarray(ref).view(np.int32))
    with pytest.raises(ValueError, match="multiple of"):
        flash_decode_attn(qf, k, v, sp, 250, 0, None, spec=_spec(bc=100),
                          interpret=True)


def test_qdecode_attn_registry_dispatch(rng):
    """core.ops.qdecode_attn routes through the backend registry: the
    jnp row is the reference, pallas-interpret the fused kernel."""
    from repro.core.ops import qdecode_attn

    qf, k, v, sp = _case(rng, c=128)
    ref = qdecode_attn(qf, k, v, sp, 200, 0, "rapid9", backend="jnp")
    got = qdecode_attn(qf, k, v, sp, 200, 0, "rapid9",
                       backend="pallas-interpret")
    np.testing.assert_array_equal(
        np.asarray(got).view(np.int32), np.asarray(ref).view(np.int32))


def test_canon_posq_shapes():
    assert canon_posq(5).shape == ()          # scalar broadcasts as-is
    assert canon_posq(jnp.asarray([1, 2, 3])).shape == (3, 1)
    assert canon_posq(jnp.asarray([[7], [8]])).shape == (2, 1)


def test_decode_attention_layer_uses_fused_kernel(rng):
    """models.layers.decode_attention on the pallas-interpret backend
    lowers to a single fused pallas_call (no separate combine pass) and
    agrees with the jnp path."""
    from repro.analysis.capture import capture_pallas_calls
    from repro.configs.base import ApproxConfig
    from repro.models import layers

    b, kv, g, hd, c = 2, 2, 2, 32, 64
    q = jnp.asarray(rng.normal(size=(b, kv * g, hd)), jnp.float32)
    k_cache = jnp.asarray(rng.normal(size=(b, c, kv, hd)), jnp.float32)
    v_cache = jnp.asarray(rng.normal(size=(b, c, kv, hd)), jnp.float32)
    sp = jnp.asarray(rng.integers(0, 40, size=(b, c)), jnp.int32)

    def run(backends):
        acfg = ApproxConfig(mul_scheme="rapid10", div_scheme="rapid9",
                            backends=backends)
        return layers.decode_attention(q, k_cache, v_cache, sp, 50, 0, acfg)

    ref = run("jnp")
    got = run("pallas-interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)
    with capture_pallas_calls() as calls:
        run("pallas-interpret")
    names = [(c.kernel_name, c.kernel_file) for c in calls]
    assert len(calls) == 1 and "_flash_kernel" in calls[0].kernel_name, (
        f"expected exactly the fused flash call, saw {names}")
