"""Backend-parity CI sweep (ROADMAP item): scheme x activation x
epilogue x shape bit-exactness between the jnp oracle and the Pallas
kernels under the interpreter.

This is the gate for kernel rewrites: every epilogue-menu composition
(bias / activation / residual-add / rms-normalize / softmax-combine)
must agree bit-for-bit across the grid.  The oracle side runs *jitted*
— models always execute compiled, and compositions where a mul-tailed
activation (silu/gelu) feeds the residual add are algebraically
rewritten by XLA inside a compiled module (see
``backend.apply_epilogue_tile``'s compilation-context note), so
compiled-vs-compiled is the parity that actually ships.

Run via the dedicated CI job: ``pytest -m parity``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as be
from repro.core.ops import qmatmul

pytestmark = pytest.mark.parity

# mul scheme -> div scheme used by its norm epilogues (the pairing the
# launcher ships: rapid10 multipliers with the rapid9 divider, etc.)
SCHEMES = {
    "mitchell": "mitchell",
    "rapid3": "rapid3",
    "rapid5": "rapid5",
    "rapid10": "rapid9",
}

# (M, K, N): single-K-block shapes (K <= 512 after padding) so the jnp
# scan at chunk=1 accumulates in the kernel's slab order; N spans
# lane-aligned, heavily-padded and multi-lane widths.
SHAPES = [
    (5, 40, 24),
    (16, 96, 128),
    (9, 200, 130),
]

# the epilogue menu: every stage alone plus full block tails
EPILOGUES = {
    "bias": dict(bias=True),
    "bias_act": dict(bias=True, ep=be.Epilogue(activation="silu")),
    "residual": dict(residual=True),
    "act_residual": dict(bias=True, residual=True,
                         ep=be.Epilogue(activation="relu")),
    "rms": dict(ep=be.Epilogue(norm="rms")),
    "softmax": dict(ep=be.Epilogue(norm="softmax")),
    "full_tail_rms": dict(bias=True, residual=True,
                          ep=be.Epilogue(activation="silu", norm="rms",
                                         keep_prenorm=True)),
    "full_tail_softmax": dict(bias=True, residual=True,
                              ep=be.Epilogue(activation="relu",
                                             norm="softmax")),
}


def _operands(shape, rng):
    m, k, n = shape
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    return x, w, b, r


def _with_div_scheme(ep, div_scheme):
    if ep is None or ep.norm is None:
        return ep
    import dataclasses

    return dataclasses.replace(ep, div_scheme=div_scheme)


def _assert_bitexact(a, b):
    tree_a = a if isinstance(a, tuple) else (a,)
    tree_b = b if isinstance(b, tuple) else (b,)
    for ga, gb in zip(tree_a, tree_b):
        np.testing.assert_array_equal(
            np.asarray(ga).view(np.int32), np.asarray(gb).view(np.int32))


def _run_pair(shape, scheme, spec, div_scheme, rng):
    x, w, b, r = _operands(shape, rng)
    ep = _with_div_scheme(spec.get("ep"), div_scheme)
    kw = dict(
        bias=b if spec.get("bias") else None,
        residual=r if spec.get("residual") else None,
        epilogue=ep,
    )
    oracle = jax.jit(functools.partial(
        qmatmul, scheme=scheme, chunk=1, backend="jnp", **kw))
    got_jnp = oracle(x, w)
    got_pal = qmatmul(x, w, scheme, backend="pallas-interpret", **kw)
    _assert_bitexact(got_jnp, got_pal)


@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
@pytest.mark.parametrize("name", sorted(EPILOGUES))
@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_epilogue_menu_bitexact(scheme, name, shape, rng):
    """Every fused epilogue composition is bit-exact between the jnp
    oracle (chunk=1, jitted) and the fused kernel under the interpreter
    across the scheme x shape grid."""
    _run_pair(shape, scheme, EPILOGUES[name], SCHEMES[scheme], rng)


@pytest.mark.parametrize("activation",
                         [None, "relu", "silu", "gelu", "gelu_erf", "tanh"])
@pytest.mark.parametrize("shape", SHAPES[:2],
                         ids=lambda s: "x".join(map(str, s)))
def test_activation_sweep_full_tail_bitexact(activation, shape, rng):
    """Activation axis of the sweep: every registered activation inside
    the full block tail norm(act(x @ w + b) + residual), pair output."""
    spec = dict(bias=True, residual=True,
                ep=be.Epilogue(activation=activation, norm="rms",
                               keep_prenorm=True))
    _run_pair(shape, "rapid10", spec, "rapid9", rng)


def test_parity_marker_registered(pytestconfig):
    """The sweep must stay selectable as its own CI job (`-m parity`)."""
    markers = pytestconfig.getini("markers")
    assert any(str(m).startswith("parity") for m in markers)


# --------------------------------------------------------------------------
# pipeline-depth axis: the KernelSpec depth knob is schedule-only.
# Depth 1 is the grid formulation, depth >= 2 the manual async-copy
# pipeline; both must agree bit-for-bit with the jnp oracle.
# --------------------------------------------------------------------------

DEPTHS = (1, 2, 3)


def _depth_spec(depth):
    from repro.kernels.spec import KernelSpec, PipelineSpec

    return KernelSpec(pipeline=PipelineSpec(depth=depth))


@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
@pytest.mark.parametrize("scheme", sorted(SCHEMES))
def test_pipeline_depth_matmul_bitexact(scheme, shape, depth, rng):
    from repro.kernels.log_matmul.ops import log_matmul

    x, w, _, _ = _operands(shape, rng)
    oracle = jax.jit(functools.partial(
        qmatmul, scheme=scheme, chunk=1, backend="jnp"))(x, w)
    got = log_matmul(x, w, scheme, interpret=True, spec=_depth_spec(depth))
    _assert_bitexact(oracle, got)


@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
def test_pipeline_depth_full_tail_bitexact(shape, depth, rng):
    """Depth axis composed with the heaviest epilogue (bias + silu +
    residual + rms keep_prenorm): the epilogue runs once per output
    tile after the K scan in both formulations."""
    from repro.kernels.log_matmul.ops import log_matmul

    x, w, b, r = _operands(shape, rng)
    ep = be.Epilogue(activation="silu", norm="rms", div_scheme="rapid9",
                     keep_prenorm=True)
    oracle = jax.jit(functools.partial(
        qmatmul, scheme="rapid10", chunk=1, backend="jnp", bias=b,
        residual=r, epilogue=ep))(x, w)
    got = log_matmul(x, w, "rapid10", bias=b, residual=r, epilogue=ep,
                     interpret=True, spec=_depth_spec(depth))
    _assert_bitexact(oracle, got)


@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("rows,cols", [(5, 40), (64, 1000), (128, 4096)])
@pytest.mark.parametrize("family", ["softmax", "rms", "rowbcast"])
def test_pipeline_depth_fused_div_bitexact(family, rows, cols, depth, rng):
    e = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    spec = _depth_spec(depth)
    if family == "softmax":
        from repro.kernels.fused_div.ops import fused_softmax_div

        oracle = be.softmax_div(e, "rapid9", backend="jnp")
        got = fused_softmax_div(e, "rapid9", spec=spec, interpret=True)
    elif family == "rms":
        from repro.kernels.fused_div.ops import fused_rms_div

        oracle = be.rms_div(e, 1e-6, "rapid9", backend="jnp")
        got = fused_rms_div(e, 1e-6, "rapid9", spec=spec, interpret=True)
    else:
        from repro.kernels.fused_div.ops import fused_elementwise_div

        d = jnp.asarray(rng.normal(size=(rows, 1)) + 4.0, jnp.float32)
        oracle = be.div(e, d, "rapid9", backend="jnp")
        got = fused_elementwise_div(e, d, "rapid9", spec=spec,
                                    interpret=True)
    _assert_bitexact(oracle, got)
