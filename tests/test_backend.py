"""Backend registry: selection precedence, batched qmatmul fwd+grad vs the
exact oracle, fused-epilogue + divider-family parity between jnp and
pallas-interpret, the memoized LUT caches, and the pinned-backend
threading regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as be
from repro.core import float_approx as fa
from repro.core import mitchell, schemes
from repro.core.ops import (
    qdiv,
    qmatmul,
    qmatmul_batched,
    qrms_div,
    qsoftmax_div,
)


# --------------------------------------------------------------------------
# registry + selection
# --------------------------------------------------------------------------

def test_builtin_backends_registered():
    names = be.available_backends()
    for expected in ("jnp", "pallas", "pallas-interpret"):
        assert expected in names


def test_resolution_precedence(monkeypatch):
    # baseline: CPU autodetect -> jnp
    monkeypatch.delenv(be.ENV_VAR, raising=False)
    be.set_default_backend(None)
    assert be.resolve_backend_name(None) == "jnp"
    assert be.resolve_backend_name("auto") == "jnp"
    # process default beats autodetect
    be.set_default_backend("pallas-interpret")
    try:
        assert be.resolve_backend_name(None) == "pallas-interpret"
        # env var beats process default
        monkeypatch.setenv(be.ENV_VAR, "pallas")
        assert be.resolve_backend_name(None) == "pallas"
        # explicit argument beats everything
        assert be.resolve_backend_name("jnp") == "jnp"
    finally:
        be.set_default_backend(None)


def test_unknown_backend_raises(monkeypatch):
    monkeypatch.delenv(be.ENV_VAR, raising=False)
    with pytest.raises(KeyError):
        be.resolve_backend_name("not-a-backend")
    monkeypatch.setenv(be.ENV_VAR, "not-a-backend")
    with pytest.raises(KeyError):
        be.resolve_backend_name(None)


def test_register_backend_no_silent_overwrite():
    jnp_backend = be.get_backend("jnp")
    with pytest.raises(ValueError):
        be.register_backend(jnp_backend)


def test_qdiv_routes_through_registry():
    a = jnp.asarray([3.0, 10.0], jnp.float32)
    b = jnp.asarray([2.0, 4.0], jnp.float32)
    got = qdiv(a, b, "rapid9", backend="jnp")
    want = fa.approx_div(a, b, "rapid9")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# hardware probe memoization + manual-mesh (shard_map)-aware autodetect
# --------------------------------------------------------------------------

@pytest.fixture
def fake_devices(monkeypatch):
    """Patch the (platform, n_devices) the probe samples; always leaves
    the memo invalidated so later tests re-probe the real hardware."""

    def set_probe(platform, n_devices):
        monkeypatch.setattr(jax, "default_backend", lambda: platform)
        monkeypatch.setattr(jax, "device_count", lambda: n_devices)
        be.invalidate_device_probe()

    yield set_probe
    be.invalidate_device_probe()


def test_device_probe_memoized_with_invalidation_hook(monkeypatch):
    """resolve_backend_name runs per dispatch; the device probe must be
    sampled once, and invalidate_device_probe() must force a resample
    (the hook tests faking device counts rely on)."""
    calls = {"n": 0}
    real_count = jax.device_count()

    def counting_device_count():
        calls["n"] += 1
        return real_count

    monkeypatch.setattr(jax, "device_count", counting_device_count)
    be.invalidate_device_probe()
    try:
        monkeypatch.delenv(be.ENV_VAR, raising=False)
        for _ in range(5):
            be.resolve_backend_name(None)
        assert calls["n"] == 1
        be.invalidate_device_probe()
        be.resolve_backend_name(None)
        assert calls["n"] == 2
    finally:
        be.invalidate_device_probe()


def test_autodetect_multidevice_tpu_is_manual_region_aware(
        fake_devices, monkeypatch):
    """On a multi-device TPU the hardware level answers per call site:
    jnp from the global (pjit) view, pallas when the call is device-
    local — either declared (device_local=True) or detected via the
    axis env inside a real shard_map body."""
    from jax.sharding import PartitionSpec

    from repro import compat

    monkeypatch.delenv(be.ENV_VAR, raising=False)
    be.set_default_backend(None)
    fake_devices("tpu", 8)
    assert be.resolve_backend_name(None) == "jnp"
    assert be.resolve_backend_name(None, device_local=True) == "pallas"
    assert be.resolve_backend_name(None, device_local=False) == "jnp"

    seen = []
    mesh = jax.make_mesh((1,), ("d",))

    def body(v):
        seen.append(be.resolve_backend_name(None))
        return v

    compat.shard_map(body, mesh=mesh, in_specs=PartitionSpec("d"),
                     out_specs=PartitionSpec("d"), check_vma=False)(
        jnp.arange(4, dtype=jnp.float32))
    assert seen == ["pallas"]

    # single-device TPU: pallas unconditionally (as before)
    fake_devices("tpu", 1)
    assert be.resolve_backend_name(None) == "pallas"
    # CPU: jnp regardless of locality
    fake_devices("cpu", 8)
    assert be.resolve_backend_name(None, device_local=True) == "jnp"


def test_pin_defers_only_the_context_dependent_hardware_level(
        fake_devices, monkeypatch):
    """pin_backends collapses arg/env/default eagerly; only on a multi-
    device TPU does the hardware level pin as AUTO_HW — and AUTO_HW then
    resolves from the memoized probe + trace context alone, so env-var
    changes after the pin cannot flip the kernel choice."""
    from repro.configs.base import BACKEND_SITES, ApproxConfig

    monkeypatch.delenv(be.ENV_VAR, raising=False)
    be.set_default_backend(None)

    # CPU: concrete pin, exactly as before
    fake_devices("cpu", 8)
    pinned = be.pin_backends(ApproxConfig())
    for site in ("default",) + BACKEND_SITES:
        assert pinned.backend_for(site) == "jnp"

    # multi-device TPU: the hardware answer depends on the call site
    fake_devices("tpu", 8)
    pinned = be.pin_backends(ApproxConfig())
    for site in ("default",) + BACKEND_SITES:
        assert pinned.backend_for(site) == be.AUTO_HW
    # global view -> jnp; device-local (shard_map body) view -> pallas
    assert be.resolve_backend_name(be.AUTO_HW) == "jnp"
    assert be.resolve_backend_name(be.AUTO_HW, device_local=True) == "pallas"
    # the pin property: env changes after build don't reach AUTO_HW
    monkeypatch.setenv(be.ENV_VAR, "pallas-interpret")
    assert be.resolve_backend_name(be.AUTO_HW) == "jnp"
    assert be.resolve_backend_name(be.AUTO_HW, device_local=True) == "pallas"
    monkeypatch.delenv(be.ENV_VAR, raising=False)

    # explicit names and env still pin concretely on the same hardware
    assert be.pin_backends(ApproxConfig(), "jnp").backend_for("mlp") == "jnp"
    monkeypatch.setenv(be.ENV_VAR, "pallas-interpret")
    assert (be.pin_backends(ApproxConfig()).backend_for("mlp")
            == "pallas-interpret")


def test_moe_manual_acfg_resolves_device_local(fake_devices, monkeypatch):
    """The MoE layer resolves its expert-compute backend from the
    device-local view before building shard_map bodies: a pinned AUTO_HW
    becomes the pallas kernels on a multi-device TPU."""
    from repro.configs.base import ApproxConfig
    from repro.models.moe import _manual_acfg

    monkeypatch.delenv(be.ENV_VAR, raising=False)
    be.set_default_backend(None)
    fake_devices("tpu", 8)
    pinned = be.pin_backends(ApproxConfig(mul_scheme="rapid10"))
    assert pinned.backend_for("mlp") == be.AUTO_HW
    assert _manual_acfg(pinned).backend_for("mlp") == "pallas"
    # explicit per-site names pass through untouched
    explicit = ApproxConfig(mul_scheme="rapid10", backends="pallas-interpret")
    assert _manual_acfg(explicit).backend_for("mlp") == "pallas-interpret"
    # no active mul scheme: nothing to resolve
    inactive = ApproxConfig()
    assert _manual_acfg(inactive) is inactive


# --------------------------------------------------------------------------
# LUT memoization
# --------------------------------------------------------------------------

def test_host_lut_memoized_and_readonly():
    l1 = fa.mul_lut("rapid10")
    l2 = fa.mul_lut("rapid10")
    assert l1 is l2
    assert not l1.flags.writeable
    assert fa.div_lut("rapid9") is fa.div_lut("rapid9")


def test_device_lut_usable_after_first_call_under_jit():
    """Regression: the memoized device LUT must stay concrete even when
    the cache is first populated inside a jit trace (no tracer leak)."""
    mitchell.lut_device.cache_clear()
    a = jnp.float32(3.0)
    b = jnp.float32(5.0)
    jitted = jax.jit(lambda a, b: fa.approx_mul(a, b, "rapid5"))(a, b)
    eager = fa.approx_mul(a, b, "rapid5")  # would raise on a leaked tracer
    np.testing.assert_array_equal(np.asarray(jitted), np.asarray(eager))


def test_device_lut_memoized_per_scheme_and_dtype():
    d1 = fa.mul_lut_device("rapid10")
    d2 = fa.mul_lut_device("rapid10")
    assert d1 is d2  # one upload ever per (scheme, dtype)
    assert fa.mul_lut_device("rapid3") is not d1
    assert fa.div_lut_device("rapid9") is fa.div_lut_device("rapid9")
    np.testing.assert_array_equal(np.asarray(d1), fa.mul_lut("rapid10"))


# --------------------------------------------------------------------------
# batched qmatmul: forward + gradient vs the exact oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("xshape,wshape", [
    ((5, 32), (32, 16)),            # plain 2-D
    ((2, 3, 32), (32, 16)),         # leading batch dims on x
    ((5, 32), (32, 4, 8)),          # trailing weight dims (K, H, D)
    ((2, 3, 32), (32, 4, 8)),       # both
])
def test_batched_qmatmul_forward_matches_exact_within_pre(xshape, wshape, rng):
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(rng.normal(size=wshape), jnp.float32)
    got = qmatmul(x, w, "rapid10", backend="jnp")
    want = qmatmul(x, w, None)
    assert got.shape == want.shape == xshape[:-1] + wshape[1:]
    rel = float(jnp.abs(got - want).mean() / jnp.abs(want).mean())
    assert rel < 0.05  # aggregation keeps error near the elementwise PRE


def test_batched_qmatmul_grad_shapes_and_values_match_exact(rng):
    """w.ndim > 2: gradient shapes equal the exact path's, and the
    straight-through cotangents equal the exact matmul's cotangents."""
    x = jnp.asarray(rng.normal(size=(2, 3, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 4, 6)), jnp.float32)
    ct = jnp.asarray(rng.normal(size=(2, 3, 4, 6)), jnp.float32)

    def approx_loss(x, w):
        return (qmatmul(x, w, "rapid10", backend="jnp") * ct).sum()

    def exact_loss(x, w):
        return (qmatmul(x, w, None) * ct).sum()

    gx_a, gw_a = jax.grad(approx_loss, argnums=(0, 1))(x, w)
    gx_e, gw_e = jax.grad(exact_loss, argnums=(0, 1))(x, w)
    assert gx_a.shape == x.shape and gw_a.shape == w.shape
    np.testing.assert_allclose(np.asarray(gx_a), np.asarray(gx_e),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gw_a), np.asarray(gw_e),
                               rtol=2e-5, atol=2e-5)


def test_fused_epilogue_grads_match_exact_fused(rng):
    """bias+activation: backward differentiates the activation at the
    exact pre-activation, so grads equal the exact fused path's."""
    x = jnp.asarray(rng.normal(size=(4, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 4, 6)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)

    def approx_loss(x, w, b):
        return qmatmul(x, w, "rapid10", backend="jnp",
                       bias=b, activation="silu").sum()

    def exact_loss(x, w, b):
        return qmatmul(x, w, None, bias=b, activation="silu").sum()

    ga = jax.grad(approx_loss, argnums=(0, 1, 2))(x, w, b)
    ge = jax.grad(exact_loss, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(ga, ge):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=2e-5, atol=2e-5)


def test_qmatmul_batched_shared_leading_dims_vs_per_expert_loop(rng):
    """The MoE contraction: [E, C, K] @ [E, K, N] via one vmapped path."""
    x = jnp.asarray(rng.normal(size=(4, 5, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 16, 12)), jnp.float32)
    got = qmatmul_batched(x, w, "rapid10", backend="jnp")
    ref = jnp.stack([qmatmul(x[i], w[i], "rapid10", backend="jnp")
                     for i in range(4)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # and it differentiates (the vmapped custom_vjp)
    gx, gw = jax.grad(
        lambda x, w: qmatmul_batched(x, w, "rapid10", backend="jnp").sum(),
        argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape


def test_qmatmul_batched_shared_bias_broadcasts(rng):
    """A shared [N] bias broadcasts over the batch; per-batch [E, N]
    bias maps; anything else raises."""
    x = jnp.asarray(rng.normal(size=(3, 5, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 16, 12)), jnp.float32)
    b_shared = jnp.asarray(rng.normal(size=(12,)), jnp.float32)
    b_per = jnp.broadcast_to(b_shared, (3, 12))
    got_shared = qmatmul_batched(x, w, "rapid10", backend="jnp", bias=b_shared)
    got_per = qmatmul_batched(x, w, "rapid10", backend="jnp", bias=b_per)
    np.testing.assert_array_equal(np.asarray(got_shared), np.asarray(got_per))
    ref = jnp.stack([qmatmul(x[i], w[i], "rapid10", backend="jnp",
                             bias=b_shared) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(got_shared), np.asarray(ref))
    with pytest.raises(ValueError):
        qmatmul_batched(x, w, "rapid10", bias=jnp.zeros((5,), jnp.float32))


def test_bias_shape_validated(rng):
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)
    with pytest.raises(ValueError):
        qmatmul(x, w, None, bias=jnp.zeros((5,), jnp.float32))


# --------------------------------------------------------------------------
# fused-epilogue kernel in interpret mode: bit-for-bit vs the jnp backend
# --------------------------------------------------------------------------

@pytest.mark.parametrize("activation", [None, "relu", "silu", "gelu_erf"])
def test_fused_epilogue_jnp_vs_pallas_interpret_bitexact(activation, rng):
    """chunk=1 makes the jnp scan accumulate in the kernel's slab order,
    so the two backends must agree bit-for-bit (single K block).  gelu's
    tanh form is excluded: its mul/add chain FMA-fuses differently inside
    a pallas_call (use gelu_erf for bit-stable fusion)."""
    x = jnp.asarray(rng.normal(size=(2, 3, 40)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(40, 6, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    o_jnp = qmatmul(x, w, "rapid10", chunk=1, backend="jnp",
                    bias=b, activation=activation)
    o_pal = qmatmul(x, w, "rapid10", backend="pallas-interpret",
                    bias=b, activation=activation)
    np.testing.assert_array_equal(
        np.asarray(o_jnp).view(np.int32), np.asarray(o_pal).view(np.int32))


def test_int_kernel_lut_memoized_per_scheme_and_width():
    """Regression: rapid_mul/rapid_div used to rebuild + re-upload the
    host LUT on every call; now one device array per (scheme, width)."""
    mul10 = schemes.MUL_SCHEMES["rapid10"]
    d1 = mitchell.lut_device(mul10, 15)
    assert mitchell.lut_device(mul10, 15) is d1
    assert mitchell.lut_device(mul10, 31) is not d1
    div9 = schemes.DIV_SCHEMES["rapid9"]
    assert mitchell.lut_device(div9, 15) is mitchell.lut_device(div9, 15)
    np.testing.assert_array_equal(np.asarray(d1), mul10.lut(15))
    assert not mitchell.lut_host(mul10, 15).flags.writeable


# --------------------------------------------------------------------------
# divider family: jnp vs pallas-interpret bit-exactness sweep
# --------------------------------------------------------------------------

DIV_SWEEP_SCHEMES = ("mitchell", "rapid3", "rapid5", "rapid9")
DIV_SWEEP_SHAPES = [
    (5,),          # single unaligned row
    (3, 7),        # tiny rows, heavy lane padding
    (2, 3, 40),    # leading batch dims, unaligned width
    (4, 128),      # lane-aligned width
    (2, 5, 200),   # batch dims + cross-lane-boundary width
    (16, 1000),    # wide unaligned rows
    (300, 4096),   # the row heuristic caps bm=64 -> 5 grid steps + row
                   # padding:
                   # the kernel tile [bm, n_pad] genuinely differs from
                   # the oracle's [M, n_pad] reduction operand here
]


@pytest.mark.parametrize("scheme", DIV_SWEEP_SCHEMES)
@pytest.mark.parametrize("shape", DIV_SWEEP_SHAPES)
def test_div_family_jnp_vs_pallas_interpret_bitexact(scheme, shape, rng):
    """The whole divider registry family must agree bit-for-bit between
    the jnp oracle and the fused Pallas kernels under the interpreter
    (shared canonical semantics: repro.kernels.fused_div.ref)."""
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    e = jnp.abs(x)  # softmax combine takes non-negative exp-weights
    b = jnp.asarray(np.abs(rng.normal(size=shape)) + 0.1, jnp.float32)

    pairs = [
        (qdiv(x, b, scheme, backend="jnp"),
         qdiv(x, b, scheme, backend="pallas-interpret")),
        (qsoftmax_div(e, scheme, backend="jnp"),
         qsoftmax_div(e, scheme, backend="pallas-interpret")),
        (qrms_div(x, 1e-6, scheme, backend="jnp"),
         qrms_div(x, 1e-6, scheme, backend="pallas-interpret")),
    ]
    for got_jnp, got_pal in pairs:
        np.testing.assert_array_equal(
            np.asarray(got_jnp).view(np.int32),
            np.asarray(got_pal).view(np.int32))


def test_div_broadcast_denominator_bitexact(rng):
    """The online-softmax combine shape: [., ., 1] denominator broadcast
    over the head dim, elementwise div family on both backends."""
    acc = jnp.asarray(rng.normal(size=(2, 4, 32)), jnp.float32)
    l = jnp.asarray(np.abs(rng.normal(size=(2, 4, 1))) + 0.1, jnp.float32)
    a = qdiv(acc, l, "rapid9", backend="jnp")
    b = qdiv(acc, l, "rapid9", backend="pallas-interpret")
    assert a.shape == acc.shape
    np.testing.assert_array_equal(
        np.asarray(a).view(np.int32), np.asarray(b).view(np.int32))


def test_softmax_div_matches_composed_reference(rng):
    """qsoftmax_div == approx_div(e, lane-padded row-sum) — the fusion
    changes launches, not semantics."""
    e = jnp.asarray(np.abs(rng.normal(size=(3, 48))), jnp.float32)
    ep = jnp.pad(e, ((0, 0), (0, 128 - 48)))
    denom = jnp.maximum(jnp.sum(ep, axis=-1, keepdims=True), 1e-20)
    want = fa.approx_div(e, denom, "rapid9")
    got = qsoftmax_div(e, "rapid9", backend="jnp")
    np.testing.assert_array_equal(
        np.asarray(got).view(np.int32), np.asarray(want).view(np.int32))


def test_fused_div_ops_straight_through_grads(rng):
    """The fused divider ops carry straight-through exact gradients: the
    backward pass equals the exact composition's gradients."""
    e = jnp.asarray(np.abs(rng.normal(size=(4, 24))) + 0.1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 24)), jnp.float32)

    g_sm = jax.grad(lambda e: qsoftmax_div(e, "rapid9", "jnp").sum())(e)
    g_sm_exact = jax.grad(
        lambda e: (e / jnp.maximum(e.sum(-1, keepdims=True), 1e-20)).sum())(e)
    np.testing.assert_allclose(np.asarray(g_sm), np.asarray(g_sm_exact),
                               rtol=2e-5, atol=2e-5)

    g_rms = jax.grad(lambda x: qrms_div(x, 1e-6, "rapid9", "jnp").sum())(x)
    g_rms_exact = jax.grad(
        lambda x: (x / jnp.sqrt(jnp.mean(jnp.square(x), -1, keepdims=True)
                                + 1e-6)).sum())(x)
    np.testing.assert_allclose(np.asarray(g_rms), np.asarray(g_rms_exact),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# pinned backend reaches every divide site
# --------------------------------------------------------------------------

def _jaxpr_has_pallas(jaxpr) -> bool:
    return "pallas_call" in str(jaxpr)


def test_pinned_backend_reaches_every_divide_site(monkeypatch):
    """Regression: layers used to drop the backend argument at all four
    qdiv call sites, so the engine/trainstep-pinned backend never reached
    the divider and divides silently re-resolved from env/default.  With
    'jnp' pinned and the env pointing at pallas, no pallas divide may be
    traced; with pallas-interpret pinned and the env unset, every divide
    site must trace the fused kernel."""
    from repro.configs.base import ApproxConfig
    from repro.models import layers

    norm_p = {"scale": jnp.ones((64,), jnp.float32),
              "bias": jnp.zeros((64,), jnp.float32)}
    x = jnp.ones((2, 64), jnp.float32)
    q = jnp.ones((1, 4, 2, 8), jnp.float32)
    kv = jnp.ones((1, 4, 2, 8), jnp.float32)
    pos = jnp.arange(4)
    acc = jnp.ones((1, 4, 2, 8), jnp.float32)
    l = jnp.ones((1, 4, 2), jnp.float32)
    m = jnp.zeros((1, 4, 2), jnp.float32)

    def traces(acfg):
        return [
            jax.make_jaxpr(
                lambda x: layers.rms_norm(x, norm_p, 1e-6, acfg))(x),
            jax.make_jaxpr(
                lambda x: layers.layer_norm(x, norm_p, 1e-6, acfg))(x),
            jax.make_jaxpr(
                lambda q, kv: layers._attn_qchunk_core(
                    q, kv, kv, pos, pos, 0, True, acfg))(q, kv),
            jax.make_jaxpr(
                lambda acc, l, m: layers._online_softmax_combine(
                    acc, l, m, acfg))(acc, l, m),
        ]

    # pinned jnp + env pointing elsewhere -> the pin must win everywhere
    monkeypatch.setenv(be.ENV_VAR, "pallas-interpret")
    pinned_jnp = ApproxConfig(div_scheme="rapid9", backends="jnp")
    for jaxpr in traces(pinned_jnp):
        assert not _jaxpr_has_pallas(jaxpr), jaxpr

    # pinned pallas-interpret + env unset -> every site traces the kernel
    monkeypatch.delenv(be.ENV_VAR, raising=False)
    pinned_pal = ApproxConfig(div_scheme="rapid9", backends="pallas-interpret")
    for jaxpr in traces(pinned_pal):
        assert _jaxpr_has_pallas(jaxpr), jaxpr


def test_per_site_backend_overrides(monkeypatch):
    """One config can mix backends per site: pallas-interpret MLP
    matmuls with jnp logits in the same model."""
    from repro.configs.base import ApproxConfig
    from repro.models import layers

    monkeypatch.delenv(be.ENV_VAR, raising=False)
    acfg = ApproxConfig(
        mul_scheme="rapid10", on_logits=True,
        backends={"mlp": "pallas-interpret", "logits": "jnp",
                  "default": "jnp"})
    x = jnp.ones((2, 32), jnp.float32)
    w = jnp.ones((32, 16), jnp.float32)
    mlp_jaxpr = jax.make_jaxpr(
        lambda x: layers.dense(x, w, acfg, "mlp"))(x)
    logits_jaxpr = jax.make_jaxpr(
        lambda x: layers.dense(x, w, acfg, "logits"))(x)
    assert _jaxpr_has_pallas(mlp_jaxpr)
    assert not _jaxpr_has_pallas(logits_jaxpr)
    # sites without their own entry defer to "default"
    attn_jaxpr = jax.make_jaxpr(
        lambda x: layers.dense(x, w, acfg, "attn_proj"))(x)
    assert not _jaxpr_has_pallas(attn_jaxpr)


def test_backend_alias_and_site_map():
    """The deprecated `backend`/`matmul_backend` read aliases are gone:
    any read raises AttributeError (lint rule RPD009 hard-errors on
    source sites); with_backends merges; unknown sites raise."""
    from repro.configs.base import ApproxConfig

    acfg = ApproxConfig(backends="jnp")
    with pytest.raises(AttributeError):
        acfg.backend  # noqa: B018 — removed alias must not resolve
    with pytest.raises(AttributeError):
        acfg.matmul_backend  # noqa: B018
    assert acfg.backend_for("mlp") == "jnp"  # defers to default
    merged = acfg.with_backends({"mlp": "pallas-interpret"})
    assert merged.backend_for("mlp") == "pallas-interpret"
    assert merged.backend_for("norm") == "jnp"  # default preserved
    # an explicit per-site "auto" defers to the default entry, exactly
    # like an absent entry (it must NOT leapfrog straight to env/hw)
    explicit_auto = ApproxConfig(backends={"mlp": "auto", "default": "jnp"})
    assert explicit_auto.backend_for("mlp") == "jnp"
    reset = merged.with_backends("pallas-interpret")
    assert reset.backend_for("mlp") == "pallas-interpret"
    assert reset.backend_for("logits") == "pallas-interpret"
    with pytest.raises((AttributeError, TypeError)):
        acfg.backend = "pallas"  # frozen dataclass, and no alias slot
    with pytest.raises(KeyError):
        ApproxConfig(backends={"not_a_site": "jnp"})
    with pytest.raises(KeyError):
        acfg.backend_for("not_a_site")


def test_pin_backends_resolves_every_site(monkeypatch):
    """pin_backends collapses auto at every site through the selection
    function once; an explicit override wins everywhere."""
    from repro.configs.base import BACKEND_SITES, ApproxConfig

    monkeypatch.setenv(be.ENV_VAR, "pallas-interpret")
    pinned = be.pin_backends(ApproxConfig(backends={"mlp": "jnp"}))
    assert pinned.backend_for("mlp") == "jnp"       # explicit site kept
    for site in ("default",) + tuple(s for s in BACKEND_SITES if s != "mlp"):
        assert pinned.backend_for(site) == "pallas-interpret"  # env won
    forced = be.pin_backends(ApproxConfig(backends={"mlp": "jnp"}), "jnp")
    for site in ("default",) + BACKEND_SITES:
        assert forced.backend_for(site) == "jnp"


def test_model_with_site_backends_reaches_call_sites(monkeypatch):
    """ModelConfig.with_site_backends threads the map into the layers:
    the MLP traces the kernel while the norm divide stays on jnp."""
    from repro.configs.base import ApproxConfig, get_config
    from repro.models import layers

    monkeypatch.delenv(be.ENV_VAR, raising=False)
    cfg = get_config("yi_6b").reduced().with_(
        approx=ApproxConfig(mul_scheme="rapid10", div_scheme="rapid9")
    ).with_site_backends({"mlp": "pallas-interpret", "default": "jnp"})
    ctx = layers.ParallelCtx()
    p = {"w1": jnp.ones((cfg.d_model, cfg.d_ff), jnp.float32),
         "w3": jnp.ones((cfg.d_model, cfg.d_ff), jnp.float32),
         "w2": jnp.ones((cfg.d_ff, cfg.d_model), jnp.float32)}
    x = jnp.ones((2, 4, cfg.d_model), jnp.float32)
    mlp_jaxpr = jax.make_jaxpr(lambda x: layers.mlp(x, p, cfg, ctx))(x)
    assert _jaxpr_has_pallas(mlp_jaxpr)
    norm_p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    norm_jaxpr = jax.make_jaxpr(
        lambda x: layers.rms_norm(x, norm_p, 1e-6, cfg.approx))(
            jnp.ones((2, cfg.d_model), jnp.float32))
    assert not _jaxpr_has_pallas(norm_jaxpr)


# --------------------------------------------------------------------------
# epilogue menu: validation + straight-through gradients
# --------------------------------------------------------------------------

def test_epilogue_validation(rng):
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(8, 2, 3)), jnp.float32)
    with pytest.raises(ValueError):  # activation both ways is ambiguous
        qmatmul(x, w, "rapid10", activation="silu",
                epilogue=be.Epilogue(activation="relu"))
    with pytest.raises(ValueError):  # norm epilogues need a 2-D weight
        qmatmul(x, w3, "rapid10", epilogue=be.Epilogue(norm="rms"))
    with pytest.raises(ValueError):  # residual must match the output
        qmatmul(x, w, "rapid10",
                residual=jnp.zeros((4, 5), jnp.float32))
    with pytest.raises(ValueError):  # keep_prenorm needs a norm stage
        qmatmul(x, w, "rapid10", epilogue=be.Epilogue(keep_prenorm=True))
    with pytest.raises(KeyError):
        qmatmul(x, w, "rapid10", epilogue=be.Epilogue(norm="nope"))


def test_fused_tail_grads_match_exact_composition(rng):
    """The full block tail norm(act(x @ w + b) + r) carries straight-
    through gradients equal to the exact composition's, for both norm
    stages and for the pair output."""
    x = jnp.asarray(rng.normal(size=(4, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)

    def exact_tail(x, w, b, r, norm):
        z = jax.nn.silu(x @ w + b[None, :]) + r
        if norm == "rms":
            return z / jnp.sqrt(jnp.mean(jnp.square(z), -1, keepdims=True)
                                + 1e-6)
        return z / jnp.maximum(jnp.sum(z, -1, keepdims=True), 1e-20)

    for norm in ("rms", "softmax"):
        ep = be.Epilogue(activation="silu", norm=norm, div_scheme="rapid9")
        ga = jax.grad(lambda *a: qmatmul(
            a[0], a[1], "rapid10", backend="jnp", bias=a[2], residual=a[3],
            epilogue=ep).sum(), argnums=(0, 1, 2, 3))(x, w, b, r)
        ge = jax.grad(lambda *a: exact_tail(*a, norm).sum(),
                      argnums=(0, 1, 2, 3))(x, w, b, r)
        for a, e in zip(ga, ge):
            np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                       rtol=2e-5, atol=2e-5)

    # pair output: the pre-norm cotangent flows through both outputs
    ep = be.Epilogue(activation="silu", norm="rms", div_scheme="rapid9",
                     keep_prenorm=True)

    def loss_pair(x, w, b, r):
        tail, pre = qmatmul(x, w, "rapid10", backend="jnp", bias=b,
                            residual=r, epilogue=ep)
        return (tail * 2.0).sum() + pre.sum()

    def loss_pair_exact(x, w, b, r):
        pre = jax.nn.silu(x @ w + b[None, :]) + r
        tail = pre / jnp.sqrt(jnp.mean(jnp.square(pre), -1, keepdims=True)
                              + 1e-6)
        return (tail * 2.0).sum() + pre.sum()

    ga = jax.grad(loss_pair, argnums=(0, 1, 2, 3))(x, w, b, r)
    ge = jax.grad(loss_pair_exact, argnums=(0, 1, 2, 3))(x, w, b, r)
    for a, e in zip(ga, ge):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=2e-5, atol=2e-5)


def test_ln2_fusion_respects_norm_site_override(monkeypatch):
    """A per-site "norm" backend override must keep steering ln2's
    divide: block_apply skips the attention-out tail fusion when the
    norm and attn_proj sites route to different backends."""
    from repro.configs.base import ApproxConfig, get_config
    from repro.models.layers import ParallelCtx
    from repro.models.transformer import block_params, block_apply

    monkeypatch.delenv(be.ENV_VAR, raising=False)
    cfg = get_config("yi_6b").reduced().with_(
        approx=ApproxConfig(mul_scheme="rapid10", div_scheme="rapid9"))
    ctx = ParallelCtx()
    from repro.models.params import materialize
    p = materialize(block_params(cfg), jax.random.PRNGKey(0), "float32")
    x = jnp.ones((1, 4, cfg.d_model), jnp.float32)
    pos = jnp.arange(4)

    def n_pallas_calls(c):
        jaxpr = jax.make_jaxpr(lambda x: block_apply(
            x, p, c, ctx, pos)[0])(x)
        return str(jaxpr).count("pallas_call")

    # same backend at both sites: the fused tail traces the kernel for
    # the ln2 divide too; split sites: norm stays on jnp (fewer calls)
    fused = n_pallas_calls(cfg.with_backend("pallas-interpret"))
    split = n_pallas_calls(cfg.with_backend("pallas-interpret")
                           .with_site_backends({"norm": "jnp"}))
    assert fused > 0
    assert split < fused


def test_exact_path_carries_rapid_norm_tail(rng):
    """scheme=None (exact MXU matmul) still routes a div_scheme norm
    epilogue through the registry divider ops."""
    x = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    ep = be.Epilogue(norm="rms", div_scheme="rapid9")
    got = qmatmul(x, w, None, backend="jnp", epilogue=ep)
    want = qrms_div(x @ w, 1e-6, "rapid9", backend="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the pair output returns the plain product as the pre value
    tail, pre = qmatmul(x, w, None, backend="jnp", epilogue=be.Epilogue(
        norm="rms", div_scheme="rapid9", keep_prenorm=True))
    np.testing.assert_array_equal(np.asarray(pre), np.asarray(x @ w))
    np.testing.assert_array_equal(np.asarray(tail), np.asarray(want))


def test_parallel_ctx_axes_rejects_unknown_logical_names():
    """Sharding-constraint typos must raise instead of silently mapping
    to None (replication); DEFAULT_RULES covers the names layers use."""
    from repro.models.layers import DEFAULT_RULES, ParallelCtx

    ctx = ParallelCtx()
    assert ctx.axes("batch", "seq_act", "act_embed") is not None
    with pytest.raises(KeyError, match="seq_atc"):
        ctx.axes("batch", "seq_atc")
    for name in ("seq_act", "act_embed"):
        assert name in DEFAULT_RULES


def test_fused_epilogue_kernel_interpret_vs_reference(rng):
    """The kernel's fused activation(out+bias) equals epilogue-after-
    matmul applied to the kernel's own unfused output."""
    from repro.kernels.log_matmul.ops import log_matmul

    x = jnp.asarray(rng.normal(size=(16, 96)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(96, 24)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(24,)), jnp.float32)
    raw = log_matmul(x, w, "rapid10", interpret=True)
    fused = log_matmul(x, w, "rapid10", bias=b, activation="silu",
                       interpret=True)
    want = be.apply_epilogue(raw, b, "silu")
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))


# --------------------------------------------------------------------------
# introspection (the dispatch auditor's registry surface)
# --------------------------------------------------------------------------

def test_registered_sites_covers_config_sites():
    from repro.configs.base import BACKEND_SITES

    sites = be.registered_sites()
    assert sites[0] == "default"
    assert set(BACKEND_SITES) <= set(sites)


def test_dispatch_signature_resolves_families():
    sig = be.dispatch_signature("jnp")
    assert set(sig) == {"matmul", "div", "softmax_div", "rms_div",
                        "decode_attn"}
    for target in sig.values():
        mod, sep, qual = target.partition(":")
        assert sep and mod and qual, target


def test_dispatch_signature_unknown_backend_raises():
    with pytest.raises(KeyError, match="unknown backend"):
        be.dispatch_signature("no-such-backend")
