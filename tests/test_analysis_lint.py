"""Layer-1 lint: one positive + one negative case per RPD rule, the
marker contract, and the baseline-ratchet semantics."""
import textwrap

from repro.analysis import findings as F
from repro.analysis.findings import Finding
from repro.analysis.rules import RULES, lint_source, zone_of
from pathlib import Path

import pytest


def lint(src, zone="models", file="src/repro/models/x.py"):
    return lint_source(textwrap.dedent(src), file, zone)


def rules_of(findings):
    return sorted({f.rule for f in findings})


# --------------------------------------------------------------------------
# RPD001 — raw matmul outside core/+kernels/
# --------------------------------------------------------------------------

@pytest.mark.parametrize("expr", [
    "y = x @ w",
    "y = jnp.einsum('ij,jk->ik', x, w)",
    "y = jnp.dot(x, w)",
    "y = jnp.matmul(x, w)",
    "y = jax.lax.dot_general(x, w, dims)",
    "y = lax.dot_general(x, w, dims)",
])
def test_rpd001_positive(expr):
    got = lint(f"def f(x, w, dims):\n    {expr}\n")
    assert rules_of(got) == ["RPD001"], got


def test_rpd001_exempt_zones():
    src = "def f(x, w):\n    return x @ w\n"
    assert lint(src, zone="core", file="src/repro/core/x.py") == []
    assert lint(src, zone="kernels", file="src/repro/kernels/x.py") == []
    # the registry-routed and declared-exact spellings never flag
    ok = lint("""
        def f(x, w):
            a = qmatmul(x, w, "rapid10")
            return exact_einsum("ij,jk->ik", a, w)
    """)
    assert ok == []


# --------------------------------------------------------------------------
# RPD002 — raw true-division in the dispatch zones
# --------------------------------------------------------------------------

def test_rpd002_positive_and_zone_scoping():
    src = "def f(a, b):\n    return a / b\n"
    assert rules_of(lint(src, zone="models")) == ["RPD002"]
    assert rules_of(lint(src, zone="serve")) == ["RPD002"]
    # launch/ is an analysis zone, not a datapath zone
    assert lint(src, zone="launch", file="src/repro/launch/x.py") == []


def test_rpd002_divide_call_and_const_exemption():
    got = lint("def f(a, b):\n    return jnp.divide(a, b)\n")
    assert rules_of(got) == ["RPD002"]
    # literal-only arithmetic can never be a traced array divide
    assert lint("SCALE = 1.0 / 8\n") == []
    assert lint("def f():\n    return -2.0 / (3 * 4)\n") == []


# --------------------------------------------------------------------------
# the '# audit: exact' marker contract
# --------------------------------------------------------------------------

def test_marker_with_reason_suppresses():
    got = lint("""
        def f(a, b):
            return a / b  # audit: exact — reference arm
    """)
    assert got == []


def test_marker_without_reason_does_not_suppress():
    got = lint("""
        def f(a, b):
            return a / b  # audit: exact
    """)
    assert rules_of(got) == ["RPD002"]
    assert "missing the mandatory reason" in got[0].msg


def test_standalone_marker_covers_next_line():
    got = lint("""
        def f(a, b):
            # audit: exact — host-side metric
            return a / b
    """)
    assert got == []


def test_marker_inside_string_is_ignored():
    got = lint("""
        def f(a, b):
            s = "# audit: exact — not a comment"
            return a / b
    """)
    assert rules_of(got) == ["RPD002"]


# --------------------------------------------------------------------------
# RPD003 — LUT construction under jit
# --------------------------------------------------------------------------

def test_rpd003_lut_in_jit():
    got = lint("""
        @jax.jit
        def f(x):
            t = lut_host("mitchell", 10)
            return x
    """)
    assert rules_of(got) == ["RPD003"]


def test_rpd003_module_level_lut_ok():
    assert lint('T = lut_host("mitchell", 10)\n') == []
    # jit present but the LUT call is outside the decorated function
    got = lint("""
        T = mul_lut_device("rapid10")

        @jax.jit
        def f(x):
            return x
    """)
    assert got == []


# --------------------------------------------------------------------------
# RPD004 — literal backend strings at call sites
# --------------------------------------------------------------------------

def test_rpd004_literal_backend():
    got = lint('def f(a, b):\n    return qdiv(a, b, "r", backend="pallas")\n')
    assert rules_of(got) == ["RPD004"]


def test_rpd004_backend_for_ok():
    got = lint("""
        def f(a, b, cfg):
            return qdiv(a, b, "r", backend=cfg.backend_for("mlp"))
    """)
    assert got == []


# --------------------------------------------------------------------------
# RPD009 — deprecated ApproxConfig.backend / .matmul_backend aliases
# --------------------------------------------------------------------------

def test_rpd009_deprecated_alias_reads():
    got = lint("""
        def f(acfg):
            return acfg.backend
    """)
    assert rules_of(got) == ["RPD009"]
    # .matmul_backend is unambiguous: flagged on any base expression
    got = lint("""
        def f(model):
            return model.cfg.approx.matmul_backend
    """)
    assert rules_of(got) == ["RPD009"]


def test_rpd009_is_hard_error_not_baselineable(tmp_path):
    """An RPD009 finding fails the lint gate even when a committed
    baseline allowlists it: hard-error rules are dropped from the
    baseline before the ratchet, so the occurrence always reads as
    new."""
    from repro.analysis import lint
    from repro.analysis.rules import HARD_ERROR_RULES

    assert "RPD009" in HARD_ERROR_RULES

    root = tmp_path / "repro"
    (root / "models").mkdir(parents=True)
    bad = root / "models" / "m.py"
    bad.write_text("def f(acfg):\n    return acfg.backend\n")

    found = lint.run_lint(root)
    assert rules_of(found) == ["RPD009"]

    # bake the finding into a baseline, then prove the ratchet still
    # fails — a baselineable rule (e.g. RPD002) would pass here
    baseline = tmp_path / "base.json"
    F.dump_report(str(baseline), found, [])
    rc = lint.main(["--root", str(root), "--baseline", str(baseline)])
    assert rc == 1

    # control: the same flow with a baselineable rule is allowlisted
    bad.write_text("def f(a, b):\n    return a / b\n")
    found = lint.run_lint(root)
    assert rules_of(found) == ["RPD002"]
    F.dump_report(str(baseline), found, [])
    assert lint.main(["--root", str(root),
                      "--baseline", str(baseline)]) == 0


def test_rpd009_ignores_unrelated_backend_attrs():
    # engine/args objects carry .backend too; only ApproxConfig-shaped
    # base names are the deprecated alias
    got = lint("""
        def f(self, args):
            name = args.backend
            self.backend = be.pin_backends(self.model.cfg.approx,
                                           args.backend)
            return acfg.backend_for("mlp")
    """)
    assert got == []


# --------------------------------------------------------------------------
# misc: syntax errors surface as findings; zone mapping
# --------------------------------------------------------------------------

def test_syntax_error_is_a_finding():
    got = lint("def f(:\n")
    assert rules_of(got) == ["RPD000"]


def test_zone_of():
    assert zone_of(Path("models/layers.py")) == "models"
    assert zone_of(Path("compat.py")) == "<top>"


def test_rules_table_complete():
    assert set(RULES) == {"RPD001", "RPD002", "RPD003", "RPD004", "RPD009"}


# --------------------------------------------------------------------------
# baseline ratchet (shared by both layers)
# --------------------------------------------------------------------------

def _ast(file, code, rule="RPD002"):
    return Finding(layer="ast", rule=rule, file=file, line=1,
                   msg="m", code=code)


def test_ratchet_new_allowlisted_stale():
    base = [_ast("a.py", "x = a / b"), _ast("b.py", "y = c / d")]
    cur = [_ast("a.py", "x = a / b"),          # allowlisted
           _ast("a.py", "z = e / f")]          # new
    res = F.compare(cur, base)
    assert not res.ok
    assert [f.code for f in res.new] == ["z = e / f"]
    assert [f.code for f in res.matched] == ["x = a / b"]
    assert [f.file for f in res.stale] == ["b.py"]   # warns, doesn't fail
    assert any("stale" in w for w in res.warnings)


def test_ratchet_key_ignores_line_numbers():
    base = [_ast("a.py", "x = a / b")]
    moved = [Finding(layer="ast", rule="RPD002", file="a.py", line=99,
                     msg="m", code="x = a / b")]
    assert F.compare(moved, base).ok


def test_ratchet_multiset_second_copy_is_new():
    base = [_ast("a.py", "x = a / b")]
    cur = [_ast("a.py", "x = a / b"), _ast("a.py", "x = a / b")]
    res = F.compare(cur, base)
    assert len(res.new) == 1 and len(res.matched) == 1


def test_report_roundtrips_as_baseline(tmp_path):
    findings = [_ast("a.py", "x = a / b")]
    p = tmp_path / "r.json"
    F.dump_report(str(p), findings, [])
    assert [f.key() for f in F.load_baseline(str(p))] \
        == [findings[0].key()]
