"""Layer-2 jaxpr audit: the log-domain zero-primitive fact, zero escapes
for the layers.py attention+mlp datapath, deliberate escapes caught with
entry/primitive attribution, hazard detectors, and the jaxpr ratchet."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import findings as F
from repro.analysis.findings import UNATTRIBUTED, Finding
from repro.analysis.jaxpr_audit import (
    ENTRIES,
    audit_fn,
    duplicate_consts,
    iter_eqns,
    unhashable_leaves,
)
from repro.configs.base import RAPID, get_config


# --------------------------------------------------------------------------
# the fact the census exploits: registry ops are log-domain
# --------------------------------------------------------------------------

def test_registry_qdiv_emits_zero_div_primitives():
    """A registry-dispatched divide is bitcast + integer add + LUT gather
    — the traced jaxpr contains no ``div`` (or ``dot_general``) at all."""
    from repro.core.ops import qdiv

    a = jnp.ones((8, 8), jnp.float32)
    findings, meta = audit_fn(
        lambda x, y: qdiv(x, y, "rapid9", backend="jnp"),
        (a, a + 1.0), "qdiv_unit")
    assert meta["eqns_audited"] == 0
    assert findings == []


def test_registry_qmatmul_emits_zero_dot_primitives():
    from repro.core.ops import qmatmul

    x = jnp.ones((4, 16), jnp.float32)
    w = jnp.ones((16, 8), jnp.float32)
    _, meta = audit_fn(
        lambda a, b: qmatmul(a, b, "rapid10", backend="jnp"),
        (x, w), "qmatmul_unit")
    assert meta["eqns_audited"] == 0


# --------------------------------------------------------------------------
# layers.py attention + mlp: zero escapes under the RAPID config
# --------------------------------------------------------------------------

def _rapid_cfg():
    return get_config("yi_6b").reduced().with_(approx=RAPID)


def test_layers_attention_mlp_zero_escapes(rng):
    from repro.models.layers import ParallelCtx, attention, mlp

    cfg = _rapid_cfg()
    ctx = ParallelCtx()
    B, S, D = 2, 8, cfg.d_model
    H, KV, hd, Fd = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    attn_p = {"wq": jnp.asarray(rng.normal(size=(D, H * hd)) * 0.02,
                                jnp.float32),
              "wk": jnp.asarray(rng.normal(size=(D, KV * hd)) * 0.02,
                                jnp.float32),
              "wv": jnp.asarray(rng.normal(size=(D, KV * hd)) * 0.02,
                                jnp.float32),
              "wo": jnp.asarray(rng.normal(size=(H * hd, D)) * 0.02,
                                jnp.float32)}
    mlp_p = {"w1": jnp.asarray(rng.normal(size=(D, Fd)) * 0.02, jnp.float32),
             "w3": jnp.asarray(rng.normal(size=(D, Fd)) * 0.02, jnp.float32),
             "w2": jnp.asarray(rng.normal(size=(Fd, D)) * 0.02, jnp.float32)}
    if cfg.act != "silu":
        mlp_p.pop("w3")

    def fwd(x, ap, mp):
        out, _, _ = attention(x, ap, cfg, ctx, pos)
        return mlp(out, mp, cfg, ctx)

    findings, meta = audit_fn(fwd, (x, attn_p, mlp_p),
                              "layers_attn_mlp", static_config=cfg.approx)
    escapes = [f for f in findings if f.file != UNATTRIBUTED]
    assert escapes == [], [f.where() for f in findings]
    assert meta["retrace_hazards"] == []


def test_model_forward_entry_zero_escapes():
    """The full reduced-model forward (attention + mlp + norms + logits)
    under RAPID routes every dot/div through the registry or a declared-
    exact site."""
    fn, args, _ = ENTRIES["model_forward"]()
    findings, meta = audit_fn(fn, args, "model_forward")
    assert meta["escapes"] == 0, [f.where() for f in findings]
    assert meta["eqns_audited"] > 0  # the exact qmatmul arm is traced


# --------------------------------------------------------------------------
# deliberate escapes are caught, with entry + primitive attribution
# --------------------------------------------------------------------------

def test_deliberate_div_escape_caught():
    a = jnp.ones((8, 8), jnp.float32)
    findings, meta = audit_fn(lambda x, y: jnp.divide(x, y), (a, a),
                              "bad_div_entry")
    assert meta["escapes"] >= 1
    assert {f.primitive for f in findings} == {"div"}
    assert all(f.entry == "bad_div_entry" for f in findings)


def test_deliberate_dot_general_escape_caught():
    x = jnp.ones((4, 16), jnp.float32)
    w = jnp.ones((16, 8), jnp.float32)
    findings, _ = audit_fn(lambda a, b: a @ b, (x, w), "bad_dot_entry")
    assert {f.primitive for f in findings} == {"dot_general"}
    # attribution reaches this test file (innermost user frame)
    assert any(f.file.endswith("test_jaxpr_audit.py") for f in findings)


def test_escape_survives_jit_wrapping():
    """Escapes inside pjit sub-jaxprs are found (iter_eqns descends)."""
    a = jnp.ones((8,), jnp.float32)
    findings, meta = audit_fn(
        lambda x, y: jax.jit(lambda p, q: p / q)(x, y), (a, a + 1),
        "jitted_escape")
    assert meta["escapes"] >= 1
    assert {f.primitive for f in findings} == {"div"}


# --------------------------------------------------------------------------
# hazard detectors
# --------------------------------------------------------------------------

def test_duplicate_const_detection():
    big = np.arange(512, dtype=np.float32)
    c1, c2 = jnp.asarray(big), jnp.asarray(big.copy())
    closed = jax.make_jaxpr(lambda x: x + c1 + c2)(jnp.zeros(512))
    warns = duplicate_consts(closed)
    assert len(warns) == 1 and "2x" in warns[0]


def test_duplicate_const_ignores_small_and_distinct():
    small = jnp.asarray(np.arange(8, dtype=np.float32))
    other = jnp.asarray(np.arange(512, dtype=np.float32) + 1.0)
    base = jnp.asarray(np.arange(512, dtype=np.float32))
    closed = jax.make_jaxpr(
        lambda x: x + small.sum() + base + other)(jnp.zeros(512))
    assert duplicate_consts(closed) == []


def test_unhashable_leaves_walks_config_trees():
    assert unhashable_leaves(RAPID) == []  # frozen dataclass: hashable
    got = unhashable_leaves({"a": [1, 2], "b": 3})
    assert got == ["cfg['a']: unhashable list"]
    # container-is-the-leaf: members hash, the container doesn't
    assert unhashable_leaves({"a": 1}) == ["cfg: unhashable dict"]


# --------------------------------------------------------------------------
# jaxpr ratchet semantics
# --------------------------------------------------------------------------

def _jx(entry, prim, file, count=1):
    return Finding(layer="jaxpr", rule="escape", file=file, line=0,
                   msg="m", entry=entry, primitive=prim, count=count)


def test_jaxpr_ratchet_new_vs_allowlisted():
    base = [_jx("e1", "div", "src/repro/train/optimizer.py", count=68)]
    cur = [_jx("e1", "div", "src/repro/train/optimizer.py", count=68),
           _jx("e1", "dot_general", "src/repro/models/moe.py")]
    res = F.compare(cur, base)
    assert not res.ok
    assert [f.file for f in res.new] == ["src/repro/models/moe.py"]


def test_jaxpr_ratchet_count_growth_warns_not_fails():
    base = [_jx("e1", "div", "src/repro/train/optimizer.py", count=4)]
    cur = [_jx("e1", "div", "src/repro/train/optimizer.py", count=9)]
    res = F.compare(cur, base)
    assert res.ok
    assert any("count grew 4 -> 9" in w for w in res.warnings)


def test_jaxpr_ratchet_unattributed_warns_not_fails():
    res = F.compare([_jx("e1", "div", UNATTRIBUTED)], [])
    assert res.ok
    assert any("unattributed" in w for w in res.warnings)


def test_entries_registry_names():
    assert set(ENTRIES) == {
        "model_forward", "model_forward_moe", "model_decode",
        "model_decode_paged", "trainstep", "app_jpeg", "app_harris",
        "app_pan_tompkins"}


# --------------------------------------------------------------------------
# apps: the rapid variant is fully log-domain end to end
# --------------------------------------------------------------------------

@pytest.mark.parametrize("entry", ["app_jpeg", "app_harris",
                                   "app_pan_tompkins"])
def test_app_entries_fully_log_domain(entry):
    fn, args, _ = ENTRIES[entry]()
    _, meta = audit_fn(fn, args, entry)
    assert meta["eqns_audited"] == 0, entry
