"""Error-feedback int8 gradient compression: unbiasedness + convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.train.compression import ef_compress, ef_state
from repro.train.optimizer import OptConfig, make_optimizer


def test_error_feedback_residual_bounded():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    res = ef_state(g)
    # repeated compression of the same gradient: residual stays bounded
    # by one quantisation step and compressed sums converge to the truth
    acc = jnp.zeros_like(g["w"])
    for _ in range(50):
        comp, res = ef_compress(g, res)
        acc = acc + comp["w"]
    mean = acc / 50
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g["w"]),
                               atol=2e-2)
    step = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.abs(res["w"]).max()) <= step + 1e-6


def test_training_converges_with_compressed_grads():
    cfg = get_config("yi_6b").reduced().with_(n_layers=2, d_model=64,
                                              d_ff=128, head_dim=16)
    m = Model(cfg)
    ctx = ParallelCtx()
    params = m.init(jax.random.PRNGKey(0))
    init_opt, update = make_optimizer(OptConfig(lr=3e-3, warmup_steps=5,
                                                total_steps=40))
    opt = init_opt(params)
    res = ef_state(params)
    src = SyntheticLM(cfg.vocab_size, 32, 8)

    @jax.jit
    def step(params, opt, res, batch, i):
        loss, grads = jax.value_and_grad(
            lambda p: m.loss_fn(p, batch, ctx))(params)
        grads, res = ef_compress(grads, res)
        params, opt, gnorm = update(grads, opt, params, i)
        return params, opt, res, loss

    losses = []
    for i in range(30):
        params, opt, res, loss = step(params, opt, res, src.batch_at(i),
                                      jnp.int32(i))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25, losses[::6]
