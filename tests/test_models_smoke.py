"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward + one train step on CPU, asserting shapes and finiteness —
in exact AND RAPID-approximate modes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, RAPID, get_config
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.train.optimizer import OptConfig
from repro.train.trainstep import make_train_step

CTX = ParallelCtx()


def _batch(cfg, rng, B=2, S=16):
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S], "targets": toks[:, 1:]}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            rng, (B, cfg.frontend_seq, 1024)) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.frontend_seq, 1024)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    batch = _batch(cfg, rng)
    logits = m.forward(params, batch, CTX)
    S_total = batch["tokens"].shape[1] + (
        cfg.frontend_seq if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    rng = jax.random.PRNGKey(1)
    params = m.init(rng)
    init_opt, step = make_train_step(m, OptConfig(lr=1e-3), CTX)
    opt = init_opt(params)
    batch = _batch(cfg, rng)
    p2, o2, metrics = step(params, opt, batch, jnp.int32(0))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed (bit-level check across all leaves)
    import numpy as np
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch", ["yi_6b", "qwen3_moe_235b_a22b", "xlstm_350m"])
def test_rapid_mode_forward(arch):
    """The paper's arithmetic end-to-end inside the model forward."""
    cfg = get_config(arch).reduced().with_(approx=RAPID)
    m = Model(cfg)
    rng = jax.random.PRNGKey(2)
    params = m.init(rng)
    batch = _batch(cfg, rng)
    loss = m.loss_fn(params, batch, CTX)
    assert bool(jnp.isfinite(loss))
    # approximate loss close to exact loss (few-percent arithmetic error)
    exact = Model(get_config(arch).reduced()).loss_fn(params, batch, CTX)
    assert abs(float(loss) - float(exact)) / float(exact) < 0.2
