"""Unit tests for the HLO text analyzer on hand-crafted modules."""
from repro.launch.hlo_analysis import analyze_hlo, _shape_bytes

HLO = """
HloModule test

%region_body (arg: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %arg = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%arg), index=1
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups={{0,1}}, dimensions={1}
  %ar = f32[64,64]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%ni, %x)
}

%region_cond (arg2: (s32[], f32[64,64])) -> pred[] {
  %arg2 = (s32[], f32[64,64]) parameter(0)
  %i2 = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main.1 (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %init = (s32[], f32[64,64]) tuple(%c0, %p0)
  %w = (s32[], f32[64,64]) while(%init), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[64,64]{1,0}") == 64 * 64 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert _shape_bytes("token[]") == 0


def test_collectives_trip_weighted():
    ana = analyze_hlo(HLO)
    co = ana["collectives"]
    # all-gather: result 64*128*4 bytes, g=2 -> (g-1)/g factor, x5 trips
    assert co["all-gather"] == 64 * 128 * 4 * 0.5 * 5
    # all-reduce: 2*R*(g-1)/g with g=4, x5 trips
    assert co["all-reduce"] == 2 * 64 * 64 * 4 * 0.75 * 5
    assert co["reduce-scatter"] == 0.0
    assert co["total"] == co["all-gather"] + co["all-reduce"]
