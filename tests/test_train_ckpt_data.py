"""Training loop, checkpointing (atomicity/resume), data determinism,
MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM, host_slice
from repro.models import moe as moe_mod
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.models.params import materialize
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig, make_optimizer
from repro.train.trainstep import make_train_step

CTX = ParallelCtx()


def _tiny():
    return get_config("yi_6b").reduced().with_(n_layers=2, d_model=64,
                                               d_ff=128, head_dim=16)


def test_loss_decreases():
    cfg = _tiny()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    init_opt, step = make_train_step(m, OptConfig(lr=3e-3, warmup_steps=5,
                                                  total_steps=60), CTX)
    opt = init_opt(params)
    src = SyntheticLM(cfg.vocab_size, 32, 8)
    step = jax.jit(step, donate_argnums=(0, 1))
    losses = []
    for i in range(40):
        params, opt, mt = step(params, opt, src.batch_at(i), jnp.int32(i))
        losses.append(float(mt["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_rapid_training_works():
    """Training *through* the paper's approximate arithmetic converges."""
    from repro.configs.base import RAPID

    cfg = _tiny().with_(approx=RAPID)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    init_opt, step = make_train_step(m, OptConfig(lr=3e-3, warmup_steps=5,
                                                  total_steps=60), CTX)
    opt = init_opt(params)
    src = SyntheticLM(cfg.vocab_size, 32, 8)
    step = jax.jit(step, donate_argnums=(0, 1))
    losses = []
    for i in range(30):
        params, opt, mt = step(params, opt, src.batch_at(i), jnp.int32(i))
        losses.append(float(mt["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[::6]


def test_grad_accumulation_equivalent():
    cfg = _tiny()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab_size, 16, 8)
    batch = src.batch_at(0)
    outs = []
    for mb in (1, 4):
        init_opt, step = make_train_step(m, OptConfig(lr=1e-3), CTX,
                                         microbatches=mb)
        opt = init_opt(params)
        p2, _, mt = step(params, opt, batch, jnp.int32(0))
        outs.append(np.asarray(jax.tree.leaves(p2)[0], np.float32))
    np.testing.assert_allclose(outs[0], outs[1], atol=3e-4, rtol=3e-3)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    cfg = _tiny()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig())[0](params)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(5, params, opt, extra={"data_cursor": 5})
    mgr.save(10, params, opt)
    mgr.save(15, params, opt)
    assert sorted(mgr.all_steps()) == [10, 15]  # keep=2 pruned step 5
    assert not list(tmp_path.glob("*.tmp"))     # atomic: no temp dirs left
    step, p2, o2, extra = mgr.restore(None, params, opt)
    assert step == 15
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loop_resume_continuity(tmp_path):
    cfg = _tiny()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    init_opt, step = make_train_step(m, OptConfig(lr=1e-3), CTX)
    opt = init_opt(params)
    src = SyntheticLM(cfg.vocab_size, 16, 4)
    step = jax.jit(step, donate_argnums=(0, 1))
    lc = LoopConfig(total_steps=6, ckpt_every=3, log_every=0,
                    ckpt_dir=str(tmp_path))
    st1 = train_loop(step, params, opt, src, lc)
    # "restart": fresh params, loop resumes from the step-6 checkpoint
    params2 = m.init(jax.random.PRNGKey(9))
    opt2 = init_opt(params2)
    lc2 = LoopConfig(total_steps=10, ckpt_every=100, log_every=0,
                     ckpt_dir=str(tmp_path))
    st2 = train_loop(step, params2, opt2, src, lc2)
    assert st2.step == 10
    assert len(st2.losses) == 4  # steps 6..9 only — resumed, not restarted


def test_data_determinism_and_host_slice():
    src = SyntheticLM(1000, 8, 16, seed=7)
    b1 = src.batch_at(42)
    b2 = src.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    parts = [host_slice(b1, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])


def test_moe_routing_matches_dense_when_no_drops(rng):
    """With top-k=E (all experts) + big capacity, the sort-based router
    must equal the dense mixture computed directly."""
    cfg = get_config("qwen3_moe_235b_a22b").reduced().with_(
        n_experts=4, experts_per_token=4, capacity_factor=8.0,
        d_model=32, d_ff=16)
    p = materialize(moe_mod.moe_params(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 6, 32)), jnp.float32)
    out = moe_mod.moe_ffn(x, p, cfg, CTX)
    # dense reference: softmax over all experts (k == E)
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    gates = jax.nn.softmax(logits, -1)
    h1 = jnp.einsum("bsd,edf->bsef", x, p["w1"])
    h3 = jnp.einsum("bsd,edf->bsef", x, p["w3"])
    eo = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h1) * h3, p["w2"])
    want = (gates[..., None] * eo).sum(-2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-3)


def test_moe_capacity_drops_bounded(rng):
    """With capacity factor 1.0, outputs differ from no-drop run only by
    dropped tokens (never NaN, never exploding)."""
    cfg = get_config("qwen3_moe_235b_a22b").reduced().with_(
        n_experts=4, experts_per_token=2, d_model=32, d_ff=16)
    p = materialize(moe_mod.moe_params(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
    lo = moe_mod.moe_ffn(x, p, cfg.with_(capacity_factor=1.0), CTX)
    hi = moe_mod.moe_ffn(x, p, cfg.with_(capacity_factor=8.0), CTX)
    assert bool(jnp.isfinite(lo).all())
    assert float(jnp.abs(lo).max()) <= float(jnp.abs(hi).max()) * 4 + 1.0
