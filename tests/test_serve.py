"""Serving correctness: prefill/decode vs full forward; engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.serve.engine import ServeEngine

CTX = ParallelCtx()


def _setup(arch, f32=False, **over):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        over.setdefault("capacity_factor", 8.0)  # no drops -> comparable
    if f32:
        over["dtype"] = "float32"
    if over:
        cfg = cfg.with_(**over)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _batch(cfg, rng, B, S):
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S], "targets": toks[:, 1:]}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(rng, (B, cfg.frontend_seq, 1024)) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (B, cfg.frontend_seq, 1024)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward(arch):
    cfg, m, params = _setup(arch)
    rng = jax.random.PRNGKey(1)
    batch = _batch(cfg, rng, 2, 12)
    full = m.forward(params, batch, CTX)[:, -1]
    extra = cfg.frontend_seq if cfg.family == "vlm" else 0
    lp, cache = m.prefill(params, batch, CTX, cache_n=16 + extra)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full),
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_matches_forward(arch):
    cfg, m, params = _setup(arch)
    rng = jax.random.PRNGKey(2)
    batch = _batch(cfg, rng, 2, 12)
    extra = cfg.frontend_seq if cfg.family == "vlm" else 0
    lp, cache = m.prefill(params, batch, CTX, cache_n=16 + extra)
    nt = jnp.argmax(lp, -1).astype(jnp.int32)
    ld, cache2 = m.decode_step(params, nt, cache, CTX)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nt[:, None]], 1)
    full2 = m.forward(params, batch2, CTX)[:, -1]
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full2),
                               atol=8e-2, rtol=8e-2)
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_decode_exact_in_f32():
    """bf16 tolerance above is pure rounding: f32 must be near-exact."""
    cfg, m, params = _setup("h2o_danube_1_8b", f32=True)
    rng = jax.random.PRNGKey(3)
    batch = _batch(cfg, rng, 2, 12)
    lp, cache = m.prefill(params, batch, CTX, cache_n=16)
    nt = jnp.argmax(lp, -1).astype(jnp.int32)
    ld, _ = m.decode_step(params, nt, cache, CTX)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nt[:, None]], 1)
    full2 = m.forward(params, batch2, CTX)[:, -1]
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full2), atol=2e-4)


def test_sliding_window_ring_cache():
    """SWA decode with a ring cache smaller than the generated length."""
    cfg, m, params = _setup("h2o_danube_1_8b", f32=True,
                            sliding_window=8)
    rng = jax.random.PRNGKey(4)
    B, S = 1, 12
    toks = jax.random.randint(rng, (B, S + 8), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    lp, cache = m.prefill(params, batch, CTX, cache_n=S + 8)
    assert cache["layers"]["l0"]["k"].shape[1] == 8  # ring == window
    # decode 4 tokens; reference = full forward each time
    cur = toks[:, :S]
    tok = jnp.argmax(lp, -1).astype(jnp.int32)
    for _ in range(4):
        ld, cache = m.decode_step(params, tok, cache, CTX)
        cur = jnp.concatenate([cur, tok[:, None]], 1)
        ref = m.forward(params, {"tokens": cur}, CTX)[:, -1]
        np.testing.assert_allclose(np.asarray(ld), np.asarray(ref), atol=3e-4)
        tok = jnp.argmax(ld, -1).astype(jnp.int32)


def test_engine_generates_deterministically():
    cfg, m, params = _setup("minicpm_2b")
    eng = ServeEngine(m, params, CTX, cache_n=64)
    out1 = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new=6)
    out2 = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new=6)
    assert out1 == out2
    assert all(len(o) == 6 for o in out1)


def test_engine_never_samples_with_root_or_reused_key(monkeypatch):
    """Regression: generate() sampled the first token with the root
    PRNGKey and then split that same key in the decode loop — classic
    key reuse.  Every categorical draw must use a fresh split key."""
    cfg, m, params = _setup("minicpm_2b")
    eng = ServeEngine(m, params, CTX, cache_n=64, temperature=1.0)
    seen = []
    orig = jax.random.categorical

    def spy(key, *args, **kwargs):
        seen.append(np.asarray(key).tobytes())
        return orig(key, *args, **kwargs)

    monkeypatch.setattr(jax.random, "categorical", spy)
    eng.generate([[1, 2, 3]], max_new=4)
    assert len(seen) >= 2
    root = np.asarray(jax.random.PRNGKey(eng.seed)).tobytes()
    assert root not in seen          # the root key is only ever split
    assert len(set(seen)) == len(seen)  # and no key is used twice
