"""Serving correctness: prefill/decode vs full forward; engine behaviour;
continuous-batching engine vs the fixed-slot path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.layers import ParallelCtx
from repro.models.model import Model
from repro.serve.engine import ServeEngine
from repro.serve.paged_kv import PageAllocator, PageGeometry
from repro.serve.scheduler import ContinuousServeEngine

CTX = ParallelCtx()


def _setup(arch, f32=False, **over):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        over.setdefault("capacity_factor", 8.0)  # no drops -> comparable
    if f32:
        over["dtype"] = "float32"
    if over:
        cfg = cfg.with_(**over)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _batch(cfg, rng, B, S):
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S], "targets": toks[:, 1:]}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(rng, (B, cfg.frontend_seq, 1024)) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (B, cfg.frontend_seq, 1024)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward(arch):
    cfg, m, params = _setup(arch)
    rng = jax.random.PRNGKey(1)
    batch = _batch(cfg, rng, 2, 12)
    full = m.forward(params, batch, CTX)[:, -1]
    extra = cfg.frontend_seq if cfg.family == "vlm" else 0
    lp, cache = m.prefill(params, batch, CTX, cache_n=16 + extra)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full),
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_matches_forward(arch):
    cfg, m, params = _setup(arch)
    rng = jax.random.PRNGKey(2)
    batch = _batch(cfg, rng, 2, 12)
    extra = cfg.frontend_seq if cfg.family == "vlm" else 0
    lp, cache = m.prefill(params, batch, CTX, cache_n=16 + extra)
    nt = jnp.argmax(lp, -1).astype(jnp.int32)
    ld, cache2 = m.decode_step(params, nt, cache, CTX)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nt[:, None]], 1)
    full2 = m.forward(params, batch2, CTX)[:, -1]
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full2),
                               atol=8e-2, rtol=8e-2)
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_decode_exact_in_f32():
    """bf16 tolerance above is pure rounding: f32 must be near-exact."""
    cfg, m, params = _setup("h2o_danube_1_8b", f32=True)
    rng = jax.random.PRNGKey(3)
    batch = _batch(cfg, rng, 2, 12)
    lp, cache = m.prefill(params, batch, CTX, cache_n=16)
    nt = jnp.argmax(lp, -1).astype(jnp.int32)
    ld, _ = m.decode_step(params, nt, cache, CTX)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nt[:, None]], 1)
    full2 = m.forward(params, batch2, CTX)[:, -1]
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full2), atol=2e-4)


def test_sliding_window_ring_cache():
    """SWA decode with a ring cache smaller than the generated length."""
    cfg, m, params = _setup("h2o_danube_1_8b", f32=True,
                            sliding_window=8)
    rng = jax.random.PRNGKey(4)
    B, S = 1, 12
    toks = jax.random.randint(rng, (B, S + 8), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    lp, cache = m.prefill(params, batch, CTX, cache_n=S + 8)
    assert cache["layers"]["l0"]["k"].shape[1] == 8  # ring == window
    # decode 4 tokens; reference = full forward each time
    cur = toks[:, :S]
    tok = jnp.argmax(lp, -1).astype(jnp.int32)
    for _ in range(4):
        ld, cache = m.decode_step(params, tok, cache, CTX)
        cur = jnp.concatenate([cur, tok[:, None]], 1)
        ref = m.forward(params, {"tokens": cur}, CTX)[:, -1]
        np.testing.assert_allclose(np.asarray(ld), np.asarray(ref), atol=3e-4)
        tok = jnp.argmax(ld, -1).astype(jnp.int32)


def test_engine_generates_deterministically():
    cfg, m, params = _setup("minicpm_2b")
    eng = ServeEngine(m, params, CTX, cache_n=64)
    out1 = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new=6)
    out2 = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new=6)
    assert out1 == out2
    assert all(len(o) == 6 for o in out1)


def test_engine_never_samples_with_root_or_reused_key(monkeypatch):
    """Regression: generate() sampled the first token with the root
    PRNGKey and then split that same key in the decode loop — classic
    key reuse.  Every categorical draw must use a fresh split key."""
    cfg, m, params = _setup("minicpm_2b")
    eng = ServeEngine(m, params, CTX, cache_n=64, temperature=1.0)
    seen = []
    orig = jax.random.categorical

    def spy(key, *args, **kwargs):
        seen.append(np.asarray(key).tobytes())
        return orig(key, *args, **kwargs)

    monkeypatch.setattr(jax.random, "categorical", spy)
    eng.generate([[1, 2, 3]], max_new=4)
    assert len(seen) >= 2
    root = np.asarray(jax.random.PRNGKey(eng.seed)).tobytes()
    assert root not in seen          # the root key is only ever folded
    assert len(set(seen)) == len(seen)  # and no key is used twice


def test_engine_overflow_raises_value_error():
    cfg, m, params = _setup("minicpm_2b")
    eng = ServeEngine(m, params, CTX, cache_n=16)
    with pytest.raises(ValueError, match=r"12.*8.*20.*16"):
        eng.generate([[1] * 12], max_new=8)


def test_engine_stop_token_not_emitted():
    """Stop-token semantics: terminate the request *without* emitting."""
    cfg, m, params = _setup("minicpm_2b", f32=True)
    eng = ServeEngine(m, params, CTX, cache_n=64)
    free = eng.generate([[1, 2, 3]], max_new=6)[0]
    assert len(free) == 6
    stop = free[3]
    out = eng.generate([[1, 2, 3]], max_new=6, stop_token=stop)[0]
    assert out == free[:3] and stop not in out
    # stop on the very first sampled token -> empty output
    out0 = eng.generate([[1, 2, 3]], max_new=6, stop_token=free[0])[0]
    assert out0 == []


# --------------------------------------------------------------------------
# continuous-batching engine (scheduler + paged KV)
# --------------------------------------------------------------------------

def _cont_setup(arch="minicpm_2b", **kw):
    cfg, m, params = _setup(arch, f32=True)
    return cfg, m, params


def test_page_allocator_invariants():
    geom = PageGeometry(page_size=8, n_pages=9, pages_per_slot=4)
    assert geom.usable_pages == 8 and geom.slot_capacity == 32
    assert geom.pages_for(1) == 1 and geom.pages_for(8) == 1
    assert geom.pages_for(9) == 2
    al = PageAllocator(geom)
    a = al.alloc(3)
    b = al.alloc(5)
    assert al.alloc(1) is None and al.n_free == 0
    assert 0 not in a + b  # scratch page never handed out
    al.free(a)
    with pytest.raises(ValueError):
        al.free(a)  # double free
    al.free(b)
    assert al.n_free == geom.usable_pages


def test_continuous_matches_fixed_slot_greedy():
    """Greedy outputs are identical to the fixed-slot path per request,
    across mixed prompt lengths, chunked prefill, and slot recycling."""
    cfg, m, params = _cont_setup()
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8, 9, 10], [11, 12], [13] * 9]
    ref = [ServeEngine(m, params, CTX, cache_n=32).generate([p], max_new=6)[0]
           for p in prompts]
    eng = ContinuousServeEngine(m, params, CTX, n_slots=2, max_len=32,
                                page_size=8, prefill_chunk=4)
    out = eng.generate(prompts, max_new=6)
    assert out == ref
    # decode and prefill each compiled exactly once across the whole run
    assert eng.trace_counts == {"decode": 1, "prefill": 1}


def test_page_free_list_restored_after_burst():
    """Leak invariant: a drained burst returns every page to the free
    list and clears every page-table row and slot."""
    cfg, m, params = _cont_setup()
    eng = ContinuousServeEngine(m, params, CTX, n_slots=2, max_len=32,
                                page_size=4, prefill_chunk=8)
    prompts = [[1 + i, 2 + i, 3 + i] for i in range(7)]
    outs = eng.generate(prompts, max_new=5)
    assert all(len(o) == 5 for o in outs)
    assert eng.alloc.n_free == eng.geom.usable_pages
    assert eng.alloc.n_live == 0
    assert not eng.pending and (eng.page_table == 0).all()


def test_admission_under_full_queue():
    """More requests than slots/pages: FCFS admission drains the queue
    as slots recycle; mid-flight the queue really is backed up."""
    cfg, m, params = _cont_setup()
    eng = ContinuousServeEngine(m, params, CTX, n_slots=2, max_len=16,
                                page_size=4, n_pages=9, prefill_chunk=4)
    rids = [eng.submit([1 + i, 2 + i], max_new=4) for i in range(6)]
    assert len(eng._queue) == 6  # nothing admitted before the first step
    got = {r: [] for r in rids}

    def drain(events):
        for ev in events:
            if ev.token is not None:
                got[ev.rid].append(ev.token)

    drain(eng.step())
    assert any(s is not None for s in eng._slots)
    assert len(eng._queue) >= 2  # only n_slots admitted so far
    while eng.pending:
        drain(eng.step())
    assert all(len(got[r]) == 4 for r in rids)


def test_continuous_stop_token_and_max_new_edges():
    cfg, m, params = _cont_setup()
    eng = ContinuousServeEngine(m, params, CTX, n_slots=2, max_len=16,
                                page_size=4, prefill_chunk=4)
    free = eng.generate([[1, 2, 3]], max_new=6)[0]
    assert len(free) == 6
    # stop token terminates without being emitted
    out = eng.generate([[1, 2, 3]], max_new=6, stop_token=free[2])[0]
    assert out == free[:2] and free[2] not in out
    # stop on the first sampled token -> empty output, done event only
    evs = list(eng.stream([[1, 2, 3]], max_new=6, stop_token=free[0]))
    assert [e.token for e in evs] == [None] and evs[-1].done
    # max_new=1 emits exactly one token; exact capacity fit admits
    assert len(eng.generate([[1, 2, 3]], max_new=1)[0]) == 1
    assert len(eng.generate([[5] * 12], max_new=4)[0]) == 4  # 12+4 == 16
    # overflow raises with the offending numbers
    with pytest.raises(ValueError, match=r"13.*4.*17.*16"):
        eng.submit([5] * 13, max_new=4)
    assert eng.alloc.n_free == eng.geom.usable_pages


def test_continuous_sampling_independent_of_batch_composition():
    """fold_in(root, rid) keys: a request's sampled tokens don't depend
    on which requests co-reside in the batch."""
    cfg, m, params = _cont_setup()

    def run(prompts):
        eng = ContinuousServeEngine(m, params, CTX, n_slots=4, max_len=32,
                                    page_size=8, prefill_chunk=4,
                                    temperature=1.0, seed=7)
        return eng.generate(prompts, max_new=5)

    alone = run([[1, 2, 3]])[0]
    crowded = run([[1, 2, 3], [9, 8, 7, 6], [4, 4, 4, 4, 4, 4]])[0]
    assert alone == crowded


def test_continuous_rejects_stateful_families():
    cfg, m, params = _setup("xlstm_350m")
    with pytest.raises(ValueError, match="dense/moe"):
        ContinuousServeEngine(m, params, CTX)
