"""KernelSpec autotuner: cache document contract, key stability,
resolve_spec precedence (explicit > cache > heuristic), and parity —
every committed TUNE_baseline.json winner produces the same numerics as
the heuristic fallback (block sizes and pipeline depth are
schedule-only knobs for every family except flash-attn's cache-chunk
size, which keeps the tight-allclose contract instead).
"""
import json

import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (
    CACHE_VERSION,
    TuningCache,
    entry_key,
    shape_class,
)
from repro.kernels.spec import KernelSpec, PipelineSpec, resolve_spec


@pytest.fixture(autouse=True)
def _isolate_cache(monkeypatch):
    """Every test picks its own active cache; nothing leaks between
    tests or into the committed repo-root default."""
    yield
    autotune.set_tuning_cache(None)


def committed_cache():
    return TuningCache.load(autotune.default_cache_path())


# --------------------------------------------------------------------------
# cache document: roundtrip + validation
# --------------------------------------------------------------------------

def _entry(family="fused_softmax", shapes=(8, 128), scheme="rapid9",
           epilogue_kind="plain", bm=8, bn=None, bk=None, depth=1):
    return {"family": family, "shapes": list(shapes), "scheme": scheme,
            "epilogue_kind": epilogue_kind, "bm": bm, "bn": bn, "bk": bk,
            "depth": depth, "cost_us": 1.0, "objective": "static-model"}


def test_cache_roundtrip(tmp_path):
    cache = TuningCache.empty()
    key = entry_key("fused_softmax", (8, 128), "rapid9", "plain")
    cache.set_platform("cpu", {key: _entry()}, objective="static-model")
    p = tmp_path / "TUNE.json"
    cache.save(p)
    back = TuningCache.load(p)
    assert back.doc == cache.doc
    assert back.platforms() == ("cpu",)
    assert back.lookup("cpu", key)["bm"] == 8
    assert back.lookup("tpu", key) is None


def test_missing_cache_is_empty(tmp_path):
    cache = TuningCache.load(tmp_path / "nope.json")
    assert cache.platforms() == ()


def test_corrupt_cache_not_json(tmp_path):
    p = tmp_path / "TUNE.json"
    p.write_text("{not json")
    with pytest.raises(ValueError, match="corrupt tuning cache"):
        TuningCache.load(p)


def test_corrupt_cache_missing_platforms(tmp_path):
    p = tmp_path / "TUNE.json"
    p.write_text(json.dumps({"version": CACHE_VERSION}))
    with pytest.raises(ValueError, match="corrupt tuning cache"):
        TuningCache.load(p)


def test_stale_cache_version_mismatch(tmp_path):
    p = tmp_path / "TUNE.json"
    p.write_text(json.dumps({"version": CACHE_VERSION + 1,
                             "platforms": {}}))
    with pytest.raises(ValueError, match="stale tuning cache.*--retune"):
        TuningCache.load(p)


def test_corrupt_cache_entry_schema(tmp_path):
    p = tmp_path / "TUNE.json"
    bad = _entry()
    del bad["depth"]
    doc = {"version": CACHE_VERSION,
           "platforms": {"cpu": {"objective": "static-model",
                                 "entries": {"k": bad}}}}
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="missing fields.*depth"):
        TuningCache.load(p)
    bad = _entry(bm="eight")
    doc["platforms"]["cpu"]["entries"] = {"k": bad}
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="not an int"):
        TuningCache.load(p)


def test_corrupt_active_cache_raises_on_dispatch(tmp_path, monkeypatch):
    """A corrupt committed cache fails loudly on the first dispatch that
    consults it — not silently falling back to heuristics."""
    p = tmp_path / "TUNE.json"
    p.write_text("[]")
    monkeypatch.setenv(autotune.ENV_VAR, str(p))
    autotune.set_tuning_cache(None)  # force a reload from the env path
    with pytest.raises(ValueError, match="corrupt tuning cache"):
        resolve_spec("fused_softmax", (8, 128), scheme="rapid9")


def test_env_var_overrides_cache_path(tmp_path, monkeypatch):
    p = tmp_path / "elsewhere.json"
    monkeypatch.setenv(autotune.ENV_VAR, str(p))
    assert autotune.default_cache_path() == p
    monkeypatch.delenv(autotune.ENV_VAR)
    assert autotune.default_cache_path().name == autotune.CACHE_BASENAME


# --------------------------------------------------------------------------
# key stability: pure-python bucketing, identical across jax pins
# --------------------------------------------------------------------------

def test_shape_class_literals():
    assert shape_class("log_matmul", (512, 512, 512)) == "512x512x512"
    # dims round up to the min tile, then to the next power of two
    assert shape_class("log_matmul", (256, 256, 130)) == "256x256x256"
    assert shape_class("log_matmul", (4, 512, 512)) == "8x512x512"
    assert shape_class("fused_softmax", (64, 1000)) == "64x1024"
    assert shape_class("fused_rms", (32, 300)) == "32x512"
    assert shape_class("flash_attn", (8, 256, 4, 64)) == "r8c256g8d128"
    with pytest.raises(KeyError):
        shape_class("not_a_family", (1, 2))


def test_entry_key_literals():
    assert entry_key("fused_softmax", (8, 128), "rapid9", "plain") \
        == "fused_softmax/8x128/rapid9/plain"
    assert entry_key("log_matmul", (512, 512, 512), "rapid10", "rms+pre") \
        == "log_matmul/512x512x512/rapid10/rms+pre"
    # scheme=None is the exact arm
    assert entry_key("flash_attn", (2, 128, 8, 128), None, "plain") \
        == "flash_attn/r8c128g8d128/exact/plain"


def test_nearby_shapes_share_a_class():
    """The whole point of bucketing: dispatch shapes that tile the same
    way hit the same winner."""
    assert shape_class("fused_softmax", (60, 1000)) \
        == shape_class("fused_softmax", (64, 1024))
    assert shape_class("log_matmul", (500, 510, 512)) \
        == shape_class("log_matmul", (512, 512, 512))


# --------------------------------------------------------------------------
# resolve_spec precedence: explicit > cache > heuristic
# --------------------------------------------------------------------------

def test_cache_hit_beats_heuristic():
    autotune.set_tuning_cache(committed_cache())
    ks = resolve_spec("fused_softmax", (8, 128), scheme="rapid9",
                      platform="cpu")
    # committed winner: bm=8 depth=1; the heuristic default depth is 2
    assert (ks.bm, ks.depth) == (8, 1)


def test_explicit_spec_beats_cache():
    autotune.set_tuning_cache(committed_cache())
    explicit = KernelSpec(bm=64, pipeline=PipelineSpec(depth=3))
    ks = resolve_spec("fused_softmax", (8, 128), explicit, scheme="rapid9",
                      platform="cpu")
    assert (ks.bm, ks.depth) == (64, 3)
    # per-field: an explicit depth still takes the cached bm
    ks = resolve_spec("fused_softmax", (8, 128),
                      KernelSpec(pipeline=PipelineSpec(depth=3)),
                      scheme="rapid9", platform="cpu")
    assert (ks.bm, ks.depth) == (8, 3)


def test_empty_cache_heuristic_fallback():
    """Off-TPU / cache-miss: resolution falls through to the
    budget-derived heuristics (the former _pick_blocks/_pick_bm)."""
    autotune.set_tuning_cache(TuningCache.empty())
    ks = resolve_spec("fused_softmax", (8, 128), scheme="rapid9")
    from repro.kernels import budget
    assert (ks.bm, ks.depth) == (8, budget.PIPELINE_BUFFERS)
    ks = resolve_spec("log_matmul", (512, 512, 512), scheme="rapid10")
    assert (ks.bm, ks.bn, ks.bk) == (256, 256, 512)


def test_unknown_platform_is_a_clean_miss():
    autotune.set_tuning_cache(committed_cache())
    ks = resolve_spec("fused_softmax", (8, 128), scheme="rapid9",
                      platform="gpu")
    from repro.kernels import budget
    assert ks.depth == budget.PIPELINE_BUFFERS  # heuristic, not bm=8/d=1


def test_resolve_is_idempotent_under_cache():
    autotune.set_tuning_cache(committed_cache())
    once = resolve_spec("log_matmul", (512, 512, 512), scheme="rapid10",
                        platform="cpu")
    again = resolve_spec("log_matmul", (512, 512, 512), once,
                         scheme="rapid10", platform="cpu")
    assert once == again


# --------------------------------------------------------------------------
# committed-cache contents: coverage + parity vs the heuristic specs
# --------------------------------------------------------------------------

def test_committed_cache_covers_every_workload():
    """TUNE_baseline.json carries a winner for every tuned family x
    bench shape class, on every committed platform."""
    cache = committed_cache()
    assert set(cache.platforms()) >= {"cpu", "tpu"}
    want = {w.key for w in autotune.workloads()}
    families = {w.family for w in autotune.workloads()}
    assert families == {"log_matmul", "fused_softmax", "fused_rms",
                        "fused_div_rowbcast", "flash_attn"}
    for platform in cache.platforms():
        assert set(cache.entries(platform)) == want


def test_committed_entries_pass_the_legality_filter():
    """Every committed winner must itself be a legal candidate — the
    same budget + RPD005-008 geometry gate the tuner searched under."""
    cache = committed_cache()
    by_key = {w.key: w for w in autotune.workloads()}
    for key, entry in cache.entries("cpu").items():
        w = by_key[key]
        spec = autotune.entry_spec(entry)
        assert autotune._geometry_legal(w, spec), (key, entry)


@pytest.mark.parity
def test_committed_entries_match_heuristic_numerics():
    """Parity: for every committed winner whose resolved geometry
    differs from the heuristic fallback, driving the family wrapper
    with the tuned cache active is bit-identical to driving it with an
    empty cache — except flash-attn when the cache-chunk size changes
    the online-softmax chunking, which keeps tight allclose instead."""
    cache = committed_cache()
    by_key = {w.key: w for w in autotune.workloads()}
    checked = 0
    for key, entry in sorted(cache.entries("cpu").items()):
        w = by_key[key]
        autotune.set_tuning_cache(TuningCache.empty())
        heur = resolve_spec(w.family, w.shapes, scheme=w.scheme,
                            epilogue=w.epilogue())
        tuned = autotune.entry_spec(entry)
        autotune.set_tuning_cache(cache)
        got = resolve_spec(w.family, w.shapes, scheme=w.scheme,
                           epilogue=w.epilogue(), platform="cpu")
        # the dispatch choke point really serves the committed winner
        for f in ("bm", "bn", "bk"):
            tv = getattr(tuned, f)
            if tv is not None:
                assert getattr(got, f) == tv, (key, f)
        assert got.depth == tuned.depth, key
        if (heur.bm, heur.bn, heur.bk, heur.depth) \
                == (got.bm, got.bn, got.bk, got.depth):
            continue  # winner == heuristic: trivially identical
        out_tuned = np.asarray(w.drive(KernelSpec(), interpret=True))
        autotune.set_tuning_cache(TuningCache.empty())
        out_heur = np.asarray(w.drive(KernelSpec(), interpret=True))
        if w.family == "flash_attn" and got.bk != heur.bk:
            np.testing.assert_allclose(out_tuned, out_heur,
                                       rtol=2e-6, atol=2e-6)
        else:
            assert out_tuned.tobytes() == out_heur.tobytes(), key
        checked += 1
    # the committed file must actually exercise the non-trivial path
    assert checked >= 3


def test_tuned_audit_variants_cover_the_cache():
    """The kernel auditor re-audits every committed winner as its own
    variant, so RPD005-008 gate the cache contents in CI."""
    cache = committed_cache()
    variants = autotune.tuned_audit_variants()
    ids = {vid for vid, _, _ in variants}
    for platform in cache.platforms():
        for key in cache.entries(platform):
            assert any(vid == f"tuned/{key}"
                       or vid.startswith(f"tuned/{key}@")
                       for vid in ids), key


# --------------------------------------------------------------------------
# search strategy + retune plumbing
# --------------------------------------------------------------------------

def test_exhaustive_search_is_deterministic_argmin():
    s = autotune.ExhaustiveSearch()
    cands = [KernelSpec(bm=8), KernelSpec(bm=64), KernelSpec(bm=128)]
    costs = {8: 3.0, 64: 1.0, 128: 1.0}
    best, cost, n = s.search(cands, lambda c: costs[c.bm])
    assert (best.bm, cost, n) == (64, 1.0, 3)  # first-wins tie break
    assert s.name == "exhaustive"


def test_legal_candidates_are_deduped_and_nonempty():
    w = [x for x in autotune.workloads()
         if x.family == "fused_softmax" and x.shapes == (8, 128)][0]
    cands = autotune.legal_candidates(w)
    assert cands
    seen = {(c.bm, c.bn, c.bk, c.depth) for c in cands}
    assert len(seen) == len(cands)


def test_retune_preserves_other_platform_subtrees(tmp_path, monkeypatch):
    """A retune replaces only the platform it scored; foreign platforms'
    committed winners survive byte-for-byte."""
    p = tmp_path / "TUNE.json"
    cache = TuningCache.empty()
    key = entry_key("fused_softmax", (8, 128), "rapid9", "plain")
    foreign = {key: _entry(bm=64, depth=3)}
    cache.set_platform("tpu", foreign, objective="wall-time")
    cache.save(p)
    # shrink the sweep to one cheap workload so the test stays fast
    only = [w for w in autotune.workloads()
            if w.family == "fused_softmax" and w.shapes == (8, 128)]
    monkeypatch.setattr(autotune, "workloads", lambda: only)
    summary = autotune.retune("cpu", path=p, verbose=False)
    back = TuningCache.load(p)
    assert back.entries("tpu") == foreign
    assert set(back.entries("cpu")) == {key}
    assert summary["platform"] == "cpu"
    assert back.entries("cpu")[key]["objective"] == "static-model"
