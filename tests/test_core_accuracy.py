"""Accuracy of the RAPID arithmetic core vs the paper's Table III claims."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schemes as S
from repro.core.bitops import ilog2, ilog2_np
from repro.core.float_approx import approx_div, approx_mul
from repro.core.mitchell import mitchell_div_np, mitchell_mul_np

# paper Table III (ARE %, PRE %) upper bounds we must meet or beat,
# with a small slack since our derived partitions differ from Fig. 2
PAPER_MUL = {  # 16-bit fixed-point-value convention
    "mitchell": (3.95, 11.2),
    "rapid3": (1.05, 6.2),
    "rapid5": (0.95, 4.5),
    "rapid10": (0.64, 3.7),
}
PAPER_DIV = {
    "mitchell": (4.2, 13.1),
    "rapid3": (1.04, 5.8),
    "rapid5": (0.79, 4.4),
    "rapid9": (0.61, 3.5),
}


def _stats(approx, exact):
    re = approx / exact - 1.0
    return 100 * np.abs(re).mean(), 100 * np.abs(re).max(), 100 * re.mean()


@pytest.mark.parametrize("name", list(PAPER_MUL))
def test_mul_accuracy_16bit(name, rng):
    a = rng.integers(1, 1 << 16, 400_000)
    b = rng.integers(1, 1 << 16, 400_000)
    exact = a.astype(np.float64) * b
    approx = mitchell_mul_np(a, b, S.MUL_SCHEMES[name], 16, quantize=False)
    are, pre, bias = _stats(approx, exact)
    t_are, t_pre = PAPER_MUL[name]
    assert are <= t_are, (name, are)
    assert pre <= t_pre, (name, pre)
    if name != "mitchell":
        assert abs(bias) < 0.3, (name, bias)  # near-zero-bias claim


@pytest.mark.parametrize("name", list(PAPER_DIV))
def test_div_accuracy_16_8(name, rng):
    a = rng.integers(1, 1 << 16, 400_000)
    b = rng.integers(1, 1 << 8, 400_000)
    m = a < (b.astype(np.int64) << 8)
    a, b = a[m], b[m]
    exact = a.astype(np.float64) / b
    approx = mitchell_div_np(a, b, S.DIV_SCHEMES[name], 8, quantize=False)
    are, pre, bias = _stats(approx, exact)
    t_are, t_pre = PAPER_DIV[name]
    assert are <= t_are, (name, are)
    assert pre <= t_pre, (name, pre)


def test_mul_exhaustive_8bit_matches_paper():
    a = np.arange(1, 256)
    A, B = np.meshgrid(a, a, indexing="ij")
    exact = A.astype(np.float64) * B
    approx = mitchell_mul_np(A, B, S.MITCHELL_MUL, 8, quantize=False)
    are, pre, _ = _stats(approx, exact)
    # paper: Mitchell 8-bit ARE 3.77%, PRE 11.11%
    assert 3.5 < are < 4.0
    assert abs(pre - 100.0 / 9.0) < 0.05


def test_scaling_invariance():
    """Error statistics must be bit-width independent (paper SSIV-A)."""
    rng = np.random.default_rng(3)
    res = []
    for nb in (8, 12, 16):
        a = rng.integers(1 << (nb - 4), 1 << nb, 100_000)
        b = rng.integers(1 << (nb - 4), 1 << nb, 100_000)
        exact = a.astype(np.float64) * b
        approx = mitchell_mul_np(a, b, S.RAPID10_MUL, nb, quantize=False)
        res.append(_stats(approx, exact)[0])
    assert max(res) - min(res) < 0.15, res


def test_quantized_integer_output_truncates():
    a = np.array([58], np.uint64)
    b = np.array([18], np.uint64)
    out = mitchell_mul_np(a, b, S.MITCHELL_MUL, 8)
    assert out[0] == 992  # paper's worked example (Eq. 6)


def test_power_of_two_exact():
    a = np.asarray([2, 4, 64, 128])
    b = np.asarray([2, 8, 32, 2])
    out = mitchell_mul_np(a, b, S.MITCHELL_MUL, 8)
    np.testing.assert_array_equal(out, a * b)


def test_float_path_matches_scalar_model(rng):
    """f32 bitcast RAPID == the continuous error model within mantissa lsb."""
    a = rng.uniform(0.5, 100, 50_000).astype(np.float32)
    b = rng.uniform(0.5, 100, 50_000).astype(np.float32)
    got = np.asarray(approx_mul(jnp.asarray(a), jnp.asarray(b), "rapid10"))
    re = got / (a.astype(np.float64) * b) - 1
    assert 100 * np.abs(re).mean() < 0.64
    assert 100 * np.abs(re).max() < 3.7


def test_float_div_signs_and_edges():
    a = jnp.asarray([6.0, -6.0, 6.0, -6.0, 0.0, 1.0], jnp.float32)
    b = jnp.asarray([3.0, 3.0, -3.0, -3.0, 5.0, 0.0], jnp.float32)
    q = np.asarray(approx_div(a, b, "rapid9"))
    assert np.sign(q[0]) > 0 and np.sign(q[1]) < 0
    assert np.sign(q[2]) < 0 and np.sign(q[3]) > 0
    assert q[4] == 0.0 and np.isinf(q[5])
    np.testing.assert_allclose(np.abs(q[:4]), 2.0, rtol=0.04)


def test_ilog2_jnp_and_np():
    v = np.array([1, 2, 3, 4, 255, 256, 2**30, 2**31 - 1], np.int64)
    expect = np.array([int(x).bit_length() - 1 for x in v])
    np.testing.assert_array_equal(ilog2_np(v), expect)
    np.testing.assert_array_equal(
        np.asarray(ilog2(jnp.asarray(v, jnp.int32))), expect)
