"""End-to-end application QoR gates (paper SSV-B acceptance criteria)."""
import pytest

from repro.apps import harris, jpeg, pan_tompkins


@pytest.fixture(scope="module")
def jpeg_scores():
    return jpeg.run(("accurate", "rapid", "mitchell"), n_images=2, size=128)


def test_jpeg_rapid_psnr_gate(jpeg_scores):
    # paper gate: >= 28 dB with RAPID mul-10 / div-9
    assert jpeg_scores["rapid"] >= 28.0
    # RAPID within ~2.5 dB of accurate (paper: 30.9 -> 28.7)
    assert jpeg_scores["accurate"] - jpeg_scores["rapid"] < 2.5


def test_jpeg_rapid_beats_mitchell(jpeg_scores):
    assert jpeg_scores["rapid"] > jpeg_scores["mitchell"] + 2.0


def test_pan_tompkins_detection():
    res = pan_tompkins.run(("accurate", "rapid", "mitchell"), n_beats=25)
    assert res["rapid"]["sensitivity"] >= 0.95      # ~100% detection
    assert res["rapid"]["ppv"] >= 0.95
    assert res["rapid"]["psnr_vs_accurate_db"] >= 28.0  # paper gate
    assert (res["rapid"]["psnr_vs_accurate_db"]
            > res["mitchell"]["psnr_vs_accurate_db"])


def test_harris_correct_vectors():
    res = harris.run(("accurate", "rapid", "truncated"), n_images=2, size=128)
    assert res["rapid"] >= 90.0       # paper acceptance bar for tracking
    assert res["rapid"] > res["truncated"]  # biased truncation hurts (Fig 9)
