"""HLO analyzer correctness + multi-device sharding integration (spawned
with fake XLA devices in a subprocess so the main process keeps 1 CPU)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, roofline_terms


def test_trip_weighted_flops_exact():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    ana = analyze_hlo(hlo)
    assert ana["flops"] == 10 * 2 * 128 ** 3  # exactly trip-weighted
    assert ana["collectives"]["total"] == 0.0


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 10e9, 0.0)   # 1s compute, tiny memory
    assert t["dominant"] == "compute"
    t = roofline_terms(1e9, 819e9 * 2, 0.0)
    assert t["dominant"] == "memory"
    t = roofline_terms(1e9, 1e9, 50e9 * 3)
    assert t["dominant"] == "collective"


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models.layers import ParallelCtx
    from repro.models.model import Model
    from repro.parallel.sharding import make_rules, named_sharding_tree
    from repro.train.optimizer import OptConfig
    from repro.train.trainstep import make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen3_moe_235b_a22b").reduced().with_(
        n_experts=4, experts_per_token=2, d_model=64, d_ff=32,
        vocab_size=512, scan_layers=True, n_layers=2)
    rules = make_rules(cfg)
    ctx = ParallelCtx(mesh, rules)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    params = jax.device_put(params, named_sharding_tree(mesh, m.pspecs(rules)))
    init_opt, step = make_train_step(m, OptConfig(lr=1e-3), ctx)
    opt = init_opt(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, 512)
    batch = {"tokens": toks[:, :16], "targets": toks[:, 1:]}
    sfun = jax.jit(step, donate_argnums=(0, 1))
    losses = []
    for i in range(4):
        params, opt, mt = sfun(params, opt, batch, jnp.int32(i))
        losses.append(float(mt["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses

    # single-device reference must agree with the sharded step (1 step)
    m2 = Model(cfg)
    p2 = m2.init(jax.random.PRNGKey(0))
    ctx2 = ParallelCtx()
    init2, step2 = make_train_step(m2, OptConfig(lr=1e-3), ctx2)
    o2 = init2(p2)
    p2b, _, mt2 = jax.jit(step2)(p2, o2, batch, jnp.int32(0))
    print("OK", losses[0], float(mt2["loss"]))
    assert abs(losses[0] - float(mt2["loss"])) < 2e-2
""")


def test_sharded_training_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
