"""The benchmark-regression gate's diff logic (benchmarks/run.py).

The CI bench-gate job runs ``benchmarks.run --smoke --json BENCH_PR.json
--baseline BENCH_baseline.json``; these tests pin the comparison
semantics so the gate can't silently stop gating.
"""
import json
import subprocess
import sys

from benchmarks.run import MIN_GATED_WALL_S, compare_to_baseline


def test_identical_results_pass():
    base = {"a": {"status": "ok", "wall_s": 10.0}}
    assert compare_to_baseline(dict(base), base, tolerance=4.0) == []


def test_missing_and_failed_benchmarks_are_regressions():
    base = {"a": {"status": "ok", "wall_s": 10.0},
            "b": {"status": "ok", "wall_s": 5.0}}
    got = {"a": {"status": "failed", "wall_s": 1.0, "error": "boom"}}
    problems = compare_to_baseline(got, base, tolerance=4.0)
    assert len(problems) == 2
    assert any("a" in p and "failed" in p for p in problems)
    assert any("b" in p and "did not run" in p for p in problems)


def test_wall_time_gate_uses_tolerance_ratio():
    base = {"a": {"status": "ok", "wall_s": 10.0}}
    ok = {"a": {"status": "ok", "wall_s": 39.0}}
    slow = {"a": {"status": "ok", "wall_s": 41.0}}
    assert compare_to_baseline(ok, base, tolerance=4.0) == []
    problems = compare_to_baseline(slow, base, tolerance=4.0)
    assert problems and "exceeds" in problems[0]


def test_subsecond_baselines_are_jitter_proof():
    """A 0.01s baseline module must not fail the PR because the runner
    took 0.5s: the floor MIN_GATED_WALL_S * tolerance applies."""
    base = {"tiny": {"status": "ok", "wall_s": 0.01}}
    got = {"tiny": {"status": "ok",
                    "wall_s": MIN_GATED_WALL_S * 4.0 - 0.1}}
    assert compare_to_baseline(got, base, tolerance=4.0) == []
    too_slow = {"tiny": {"status": "ok",
                         "wall_s": MIN_GATED_WALL_S * 4.0 + 0.1}}
    assert compare_to_baseline(too_slow, base, tolerance=4.0)


def test_new_benchmarks_are_not_gated():
    base = {"a": {"status": "ok", "wall_s": 1.0}}
    got = {"a": {"status": "ok", "wall_s": 1.0},
           "brand_new": {"status": "failed", "wall_s": 0.0}}
    # the failed *new* module still fails the run via the harness exit
    # code; the baseline diff itself only gates known benchmarks (the
    # harness then auto-records new *ok* modules — see the CLI tests)
    assert compare_to_baseline(got, base, tolerance=4.0) == []


def test_cli_baseline_diff_exit_codes(tmp_path):
    """End-to-end through the argparse surface: a fabricated PR result
    vs a fabricated baseline, both regression and pass cases — without
    running any real benchmark (empty names list is rejected, so use
    the fast roofline_report module)."""
    baseline = tmp_path / "base.json"
    baseline.write_text(json.dumps({
        "smoke": True,
        "benchmarks": {"roofline_report": {"status": "ok", "wall_s": 0.1}},
    }))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "roofline_report",
         "--smoke", "--json", str(tmp_path / "pr.json"),
         "--baseline", str(baseline), "--tolerance", "4.0"],
        capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "benchmark gate OK" in r.stdout
    written = json.loads((tmp_path / "pr.json").read_text())
    assert written["benchmarks"]["roofline_report"]["status"] == "ok"

    # baseline names a module the run skipped -> regression, exit 1
    baseline.write_text(json.dumps({
        "smoke": True,
        "benchmarks": {"fused_div": {"status": "ok", "wall_s": 1.0}},
    }))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "roofline_report",
         "--smoke", "--baseline", str(baseline)],
        capture_output=True, text=True, timeout=300)
    assert r.returncode == 1
    assert "did not run" in r.stdout


def test_cli_auto_records_new_benchmark(tmp_path):
    """A module with no baseline row skips the gate once; a gated run
    must fold it into the artifact so the *second* run gates it —
    otherwise new benchmarks stay ungated forever."""
    baseline = tmp_path / "base.json"
    baseline.write_text(json.dumps({"smoke": True, "benchmarks": {}}))
    cmd = [sys.executable, "-m", "benchmarks.run", "roofline_report",
           "--smoke", "--baseline", str(baseline)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "recorded new benchmark 'roofline_report'" in r.stdout
    row = json.loads(baseline.read_text())["benchmarks"]["roofline_report"]
    assert row["status"] == "ok" and row["wall_s"] >= 0.0
    # second run: the row exists, so the diff gates it for real
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "benchmark gate OK" in r.stdout
    assert "recorded new benchmark" not in r.stdout


def test_cli_auto_record_skips_mode_mismatch(tmp_path):
    """Smoke and full walls differ by orders of magnitude: a smoke run
    against a full-mode baseline must not seed rows the full gate would
    later compare against."""
    baseline = tmp_path / "base.json"
    baseline.write_text(json.dumps({"smoke": False, "benchmarks": {}}))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "roofline_report",
         "--smoke", "--baseline", str(baseline)],
        capture_output=True, text=True, timeout=300)
    assert r.returncode == 0
    assert "recorded new benchmark" not in r.stdout
    assert json.loads(baseline.read_text())["benchmarks"] == {}
